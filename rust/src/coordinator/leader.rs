//! The leader (server) side of the coordinator: drives rounds, enforces
//! the barrier, and aggregates per-slot weighted means through a
//! **streaming, parallel decode pipeline**.
//!
//! # Streaming aggregation
//!
//! The pre-streaming leader waited for the full barrier, then decoded
//! every slot of every upload serially — at large worker counts the
//! server, not the clients, became the round bottleneck. Now each upload
//! is handed to a decode pool the moment it arrives ([`decode_upload`]
//! turns it into per-slot [`SlotPartial`]s), so decode work overlaps the
//! barrier wait; at the barrier the partials are merged in client-id
//! order ([`merge_decoded`]).
//!
//! Determinism: decoding a frame into its own zeroed accumulator is
//! order-independent, and the merge folds partials in client-id order —
//! the same rule `run_round_par` uses — so the outcome is **bit-identical
//! to the sequential sorted-decode reference**
//! ([`aggregate_uploads_reference`], kept as the executable
//! specification) for every arrival order and every decode-thread count.
//! The conformance suite in `tests/streaming_leader.rs` proves this for
//! all protocol specs × arrival orders × decode threads ∈ {1, 2, 8}.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use super::metrics::{ExperimentMetrics, RoundMetrics};
use super::transport::{Message, TransportHub, WeightedFrame};
use crate::protocol::{Decoder, Protocol, RoundCtx, RoundState, SlotPartial};

/// Result of one coordinated round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// Aggregated mean per slot (slot = position in each worker's upload,
    /// e.g. cluster index for Lloyd's; one slot for plain mean estimation).
    pub means: Vec<Vec<f32>>,
    /// Total weight per slot.
    pub weights: Vec<f64>,
    /// Exact uplink payload bits this round (sum of frame bit lengths).
    pub uplink_bits: u64,
    /// Number of non-silent frames received.
    pub n_frames: usize,
}

/// One worker's upload with every slot decoded into a [`SlotPartial`]:
/// the unit of work of the streaming pipeline. Producing it is the
/// expensive, order-independent half of server-side aggregation (bit
/// unpacking + dequantization into zeroed accumulators, on any decode
/// thread); what remains at the barrier is a cheap deterministic fold.
pub struct DecodedUpload {
    pub client: u64,
    /// One entry per uploaded slot; `None` for a silent (empty) frame,
    /// which still counts toward the slot's holder count.
    pub slots: Vec<Option<SlotPartial>>,
    /// Sum of the non-silent frames' bit lengths.
    pub uplink_bits: u64,
    /// Non-silent frame count.
    pub n_frames: usize,
}

/// Decode one worker's upload into per-slot partials. Shares only the
/// immutable round state, so uploads decode concurrently on any threads,
/// in any arrival order, without affecting the merged bits.
pub fn decode_upload(
    proto: &dyn Protocol,
    state: &RoundState,
    client: u64,
    frames: &[WeightedFrame],
) -> Result<DecodedUpload> {
    let mut slots = Vec::with_capacity(frames.len());
    let mut uplink_bits = 0u64;
    let mut n_frames = 0usize;
    for wf in frames {
        if wf.frame.bit_len == 0 {
            slots.push(None);
        } else {
            uplink_bits += wf.frame.bit_len;
            n_frames += 1;
            slots.push(Some(SlotPartial::decode(proto, state, &wf.frame, wf.weight)?));
        }
    }
    Ok(DecodedUpload { client, slots, uplink_bits, n_frames })
}

/// Merge decoded uploads into the round outcome: sort by client id, then
/// fold each slot's partials in that order through
/// [`Decoder::push_partial`]. Bit-identical to
/// [`aggregate_uploads_reference`] for any upload arrival order and any
/// decode-thread count.
pub fn merge_decoded(
    proto: &dyn Protocol,
    state: &RoundState,
    mut decoded: Vec<DecodedUpload>,
) -> RoundOutcome {
    decoded.sort_by_key(|d| d.client);
    // Slot count: max over workers (workers with empty shards send 0).
    let n_slots = decoded.iter().map(|d| d.slots.len()).max().unwrap_or(0);
    let uplink_bits = decoded.iter().map(|d| d.uplink_bits).sum();
    let n_frames = decoded.iter().map(|d| d.n_frames).sum();
    let mut means = Vec::with_capacity(n_slots);
    let mut weights = Vec::with_capacity(n_slots);
    for slot in 0..n_slots {
        let holders = decoded.iter().filter(|d| d.slots.len() > slot).count();
        let parts: Vec<&SlotPartial> = decoded
            .iter()
            .filter_map(|d| d.slots.get(slot).and_then(|p| p.as_ref()))
            .collect();
        // Plain-mean fast path iff every present frame has weight 1.0 —
        // the same branch (and therefore the same finish semantics) as
        // the sequential reference.
        let uniform = parts.iter().all(|p| p.weight == 1.0);
        let mut dec = Decoder::new(proto, state);
        for p in &parts {
            dec.push_partial(p);
        }
        if uniform {
            weights.push(dec.frames() as f64);
            means.push(dec.finish(holders));
        } else {
            weights.push(dec.total_weight());
            means.push(dec.finish_weighted());
        }
    }
    RoundOutcome { means, weights, uplink_bits, n_frames }
}

/// The pre-streaming aggregation path: sort uploads by client id, then
/// decode every slot sequentially, in place. Retained as the executable
/// bit-exact specification of what the streaming pipeline must produce;
/// the conformance suite diffs the two.
pub fn aggregate_uploads_reference(
    proto: &dyn Protocol,
    state: &RoundState,
    mut uploads: Vec<(u64, Vec<WeightedFrame>)>,
) -> Result<RoundOutcome> {
    // Deterministic aggregation: decode in client-id order regardless
    // of arrival order (f32 addition is not associative; without this
    // the same round could produce different bit patterns run-to-run).
    uploads.sort_by_key(|(client, _)| *client);
    let n_slots = uploads.iter().map(|(_, f)| f.len()).max().unwrap_or(0);
    let mut means = Vec::with_capacity(n_slots);
    let mut weights = Vec::with_capacity(n_slots);
    let mut uplink_bits = 0u64;
    let mut n_frames = 0usize;
    for slot in 0..n_slots {
        let slot_frames: Vec<&WeightedFrame> = uploads
            .iter()
            .filter_map(|(_, f)| f.get(slot))
            .filter(|wf| wf.frame.bit_len > 0)
            .collect();
        uplink_bits += slot_frames.iter().map(|wf| wf.frame.bit_len).sum::<u64>();
        n_frames += slot_frames.len();
        let holders = uploads.iter().filter(|(_, f)| f.get(slot).is_some()).count();

        let mut dec = Decoder::new(proto, state);
        let uniform = slot_frames.iter().all(|wf| wf.weight == 1.0);
        if uniform {
            for wf in &slot_frames {
                dec.push(&wf.frame)?;
            }
            weights.push(slot_frames.len() as f64);
            means.push(dec.finish(holders));
        } else {
            for wf in &slot_frames {
                dec.push_weighted(&wf.frame, wf.weight)?;
            }
            weights.push(dec.total_weight());
            means.push(dec.finish_weighted());
        }
    }
    Ok(RoundOutcome { means, weights, uplink_bits, n_frames })
}

/// Run the streaming aggregation over an already-received upload list
/// with `decode_threads` workers. Shares the determinism-relevant core
/// with [`Leader::round`] ([`decode_upload`] + [`merge_decoded`]); only
/// the task scheduling differs (a ready list here vs the channel-fed
/// pool a live round streams through — which the conformance suite also
/// exercises end to end via `Leader::round` itself). Exposed for
/// benches and the conformance suite.
pub fn aggregate_uploads_streaming(
    proto: &dyn Protocol,
    state: &RoundState,
    uploads: &[(u64, Vec<WeightedFrame>)],
    decode_threads: usize,
) -> Result<RoundOutcome> {
    let decoded = if decode_threads <= 1 {
        uploads
            .iter()
            .map(|(c, f)| decode_upload(proto, state, *c, f))
            .collect::<Result<Vec<_>>>()?
    } else {
        let next = AtomicUsize::new(0);
        let next = &next;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..decode_threads)
                .map(|_| {
                    scope.spawn(move || {
                        let mut out = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= uploads.len() {
                                break;
                            }
                            let (c, f) = &uploads[i];
                            out.push(decode_upload(proto, state, *c, f));
                        }
                        out
                    })
                })
                .collect();
            let mut all = Vec::with_capacity(uploads.len());
            for h in handles {
                for r in h.join().expect("decode thread panicked") {
                    all.push(r?);
                }
            }
            Ok::<_, anyhow::Error>(all)
        })?
    };
    Ok(merge_decoded(proto, state, decoded))
}

/// The coordinator leader.
pub struct Leader {
    protocol: Arc<dyn Protocol>,
    hub: Box<dyn TransportHub>,
    seed: u64,
    metrics: ExperimentMetrics,
    decode_threads: usize,
}

impl Leader {
    pub fn new(protocol: Arc<dyn Protocol>, hub: Box<dyn TransportHub>, seed: u64) -> Self {
        Leader { protocol, hub, seed, metrics: ExperimentMetrics::default(), decode_threads: 1 }
    }

    /// Set the decode-pool width (builder style). Any value produces
    /// bit-identical round outcomes — the merge order is fixed by client
    /// ids, never by scheduling; `0` is treated as 1.
    pub fn with_decode_threads(mut self, n: usize) -> Self {
        self.decode_threads = n.max(1);
        self
    }

    /// Change the decode-pool width on a live leader.
    pub fn set_decode_threads(&mut self, n: usize) {
        self.decode_threads = n.max(1);
    }

    pub fn decode_threads(&self) -> usize {
        self.decode_threads
    }

    pub fn n_workers(&self) -> usize {
        self.hub.n_workers()
    }

    pub fn metrics(&self) -> &ExperimentMetrics {
        &self.metrics
    }

    /// Run one synchronous round: broadcast `state` (`n_slots × dim`
    /// flattened — what the workers need to compute their updates), then
    /// stream uploads through the decode pool as they arrive and merge
    /// the partials once every worker has answered.
    pub fn round(&mut self, round: u64, dim: u32, state: &[f32]) -> Result<RoundOutcome> {
        let t0 = Instant::now();
        let n_workers = self.hub.n_workers();
        ensure!(n_workers > 0, "no workers connected");
        // The payload is Arc-shared: one allocation for the whole
        // broadcast instead of one clone per worker.
        self.hub.broadcast(&Message::RoundStart { round, dim, payload: Arc::from(state) })?;

        let ctx = RoundCtx::new(round, self.seed);
        let proto = self.protocol.clone();
        // One round session: shared state (the rotation for π_srk) is
        // prepared once and reused by every decode thread and the merge.
        let round_state = proto.prepare(&ctx);
        let decode_threads = self.decode_threads.clamp(1, n_workers);

        let decode_ns = AtomicU64::new(0);
        let mut wait_wall = Duration::ZERO;

        // Streaming barrier: the leader thread owns the transport and
        // hands each upload to the decode pool the moment it arrives, so
        // decoding overlaps the wait for slower workers. The channels
        // live outside the scope: scoped threads may only borrow data
        // that outlives the scope itself.
        let hub = &mut self.hub;
        let (task_tx, task_rx) = mpsc::channel::<(u64, Vec<WeightedFrame>)>();
        let (out_tx, out_rx) = mpsc::channel::<Result<DecodedUpload>>();
        let task_rx = Mutex::new(task_rx);
        let decoded = std::thread::scope(|scope| -> Result<Vec<DecodedUpload>> {
            for i in 0..decode_threads {
                let out_tx = out_tx.clone();
                let task_rx = &task_rx;
                let proto = proto.as_ref();
                let round_state = &round_state;
                let decode_ns = &decode_ns;
                std::thread::Builder::new()
                    .name(format!("dme-decode-{i}"))
                    .spawn_scoped(scope, move || loop {
                        // Hold the lock only for the dequeue, not the
                        // decode, so the pool drains in parallel.
                        let task = task_rx.lock().unwrap().recv();
                        let Ok((client, frames)) = task else { return };
                        let t = Instant::now();
                        let res = decode_upload(proto, round_state, client, &frames);
                        decode_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        if out_tx.send(res).is_err() {
                            return;
                        }
                    })
                    .expect("spawning decode thread");
            }
            drop(out_tx);

            // Barrier: exactly one upload per worker.
            let mut seen = HashSet::new();
            for _ in 0..n_workers {
                let t = Instant::now();
                let msg = hub.recv()?;
                wait_wall += t.elapsed();
                match msg {
                    Message::Upload { client, round: r, frames } => {
                        ensure!(r == round, "worker {client} answered round {r}, expected {round}");
                        ensure!(seen.insert(client), "duplicate upload from worker {client}");
                        task_tx.send((client, frames)).expect("decode pool hung up");
                    }
                    Message::RoundStart { .. } | Message::Shutdown => {
                        bail!("unexpected message at the leader")
                    }
                }
            }
            drop(task_tx); // pool drains the queue, then exits

            let mut decoded = Vec::with_capacity(n_workers);
            for _ in 0..n_workers {
                decoded.push(out_rx.recv().expect("decode pool died")?);
            }
            Ok(decoded)
        })?;

        let t_merge = Instant::now();
        let outcome = merge_decoded(proto.as_ref(), &round_state, decoded);
        decode_ns.fetch_add(t_merge.elapsed().as_nanos() as u64, Ordering::Relaxed);

        let (down, up) = self.hub.bytes_moved();
        self.metrics.push(RoundMetrics {
            round,
            uplink_bits: outcome.uplink_bits,
            n_frames: outcome.n_frames,
            wall: t0.elapsed(),
            wait_wall,
            decode_wall: Duration::from_nanos(decode_ns.load(Ordering::Relaxed)),
            cum_down_bytes: down,
            cum_up_bytes: up,
        });
        Ok(outcome)
    }

    /// Broadcast shutdown to all workers.
    pub fn shutdown(&mut self) -> Result<()> {
        self.hub.broadcast(&Message::Shutdown)
    }
}

/// Spawn `shards.len()` loopback worker threads plus a leader — the
/// single-process cluster used by examples, tests, and benches.
pub fn spawn_local_cluster(
    protocol: Arc<dyn Protocol>,
    shards: Vec<Vec<Vec<f32>>>,
    update: super::worker::UpdateFn,
    seed: u64,
) -> (Leader, Vec<std::thread::JoinHandle<Result<()>>>) {
    let n = shards.len();
    let (hub, endpoints) = super::transport::LoopbackHub::new(n);
    let mut handles = Vec::with_capacity(n);
    for (i, (shard, ep)) in shards.into_iter().zip(endpoints).enumerate() {
        let worker = super::worker::Worker {
            client_id: i as u64,
            shard,
            protocol: protocol.clone(),
            update: update.clone(),
            seed,
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("dme-worker-{i}"))
                .spawn(move || worker.run_loopback(ep))
                .expect("spawning worker thread"),
        );
    }
    (Leader::new(protocol, Box::new(hub), seed), handles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::mean_update;
    use crate::protocol::config::ProtocolConfig;
    use crate::stats;

    fn cluster(
        spec: &str,
        d: usize,
        shards: Vec<Vec<Vec<f32>>>,
    ) -> (Leader, Vec<std::thread::JoinHandle<Result<()>>>) {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        spawn_local_cluster(proto, shards, mean_update(), 42)
    }

    #[test]
    fn mean_estimation_round_over_loopback() {
        let d = 32;
        let shards: Vec<Vec<Vec<f32>>> =
            (0..5).map(|i| vec![vec![i as f32 * 0.1; d]]).collect();
        let client_means: Vec<Vec<f32>> =
            shards.iter().map(|s| s[0].clone()).collect();
        let truth = stats::true_mean(&client_means);
        let (mut leader, handles) = cluster("klevel:k=64", d, shards);
        let out = leader.round(0, d as u32, &[]).unwrap();
        assert_eq!(out.means.len(), 1);
        assert_eq!(out.n_frames, 5);
        assert!(out.uplink_bits > 0);
        let err = stats::sq_error(&out.means[0], &truth);
        assert!(err < 1e-3, "err={err}");
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn multiple_rounds_and_metrics() {
        let d = 16;
        let shards: Vec<Vec<Vec<f32>>> = (0..3).map(|_| vec![vec![1.0; d]]).collect();
        let (mut leader, handles) = cluster("binary", d, shards);
        for r in 0..4 {
            leader.round(r, d as u32, &[]).unwrap();
        }
        assert_eq!(leader.metrics().rounds.len(), 4);
        let m = &leader.metrics().rounds[3];
        assert_eq!(m.round, 3);
        assert!(m.cum_up_bytes >= m.uplink_bits / 8);
        assert!(m.decode_wall > Duration::ZERO, "decode wall not measured");
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn decode_pool_width_does_not_change_round_bits() {
        // Same cluster, same seeds, different decode-thread counts: the
        // estimates must agree bit for bit (the merge order is fixed by
        // client ids, not by decode scheduling).
        let d = 64;
        let mk_shards = || -> Vec<Vec<Vec<f32>>> {
            (0..9).map(|i| vec![vec![0.3 + i as f32 * 0.7; d]]).collect()
        };
        let mut reference: Option<Vec<Vec<u32>>> = None;
        for threads in [1usize, 2, 8] {
            let (mut leader, handles) = cluster("rotated:k=16", d, mk_shards());
            leader.set_decode_threads(threads);
            let mut rounds = Vec::new();
            for r in 0..3 {
                let out = leader.round(r, d as u32, &[]).unwrap();
                rounds.push(out.means[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>());
            }
            match &reference {
                None => reference = Some(rounds.concat()),
                Some(want) => {
                    assert_eq!(&rounds.concat(), want, "threads={threads} diverged");
                }
            }
            leader.shutdown().unwrap();
            for h in handles {
                h.join().unwrap().unwrap();
            }
        }
    }

    #[test]
    fn streaming_matches_reference_on_manual_uploads() {
        // Hand-built multi-slot uploads with ragged slot counts and mixed
        // weights, fed to both aggregation paths in scrambled order.
        let d = 16;
        let proto = ProtocolConfig::parse("float32", d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 5);
        let state = proto.prepare(&ctx);
        let mut enc = crate::protocol::Encoder::new(proto.as_ref(), &state);
        let mut uploads: Vec<(u64, Vec<WeightedFrame>)> = Vec::new();
        for client in 0..5u64 {
            let n_slots = 1 + (client as usize) % 3; // ragged: 1..=3 slots
            let mut frames = Vec::new();
            for slot in 0..n_slots {
                let x = vec![client as f32 + slot as f32 * 0.1; d];
                let frame = enc.encode(client * 10 + slot as u64, &x).unwrap();
                let weight = if client == 2 { 3.0 } else { 1.0 }; // mixed
                frames.push(WeightedFrame { frame, weight });
            }
            // client 4 additionally uploads a silent frame
            if client == 4 {
                frames.push(WeightedFrame {
                    frame: crate::protocol::Frame::new(Vec::new(), 0),
                    weight: 0.0,
                });
            }
            uploads.push((client, frames));
        }
        let want = aggregate_uploads_reference(proto.as_ref(), &state, uploads.clone()).unwrap();
        uploads.reverse(); // scrambled arrival
        for threads in [1usize, 2, 8] {
            let got =
                aggregate_uploads_streaming(proto.as_ref(), &state, &uploads, threads).unwrap();
            assert_eq!(got.uplink_bits, want.uplink_bits);
            assert_eq!(got.n_frames, want.n_frames);
            assert_eq!(got.weights, want.weights);
            assert_eq!(got.means.len(), want.means.len());
            for (a, b) in got.means.iter().zip(&want.means) {
                assert_eq!(
                    a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "threads={threads}"
                );
            }
        }
    }

    #[test]
    fn failing_worker_errors_the_round_instead_of_deadlocking() {
        // A worker whose step() fails (here: stream-id packing overflow)
        // sends a barrier-wakeup before dying, so the leader's round
        // returns Err instead of blocking forever on the barrier.
        let d = 8;
        let proto = ProtocolConfig::parse("klevel:k=4", d).unwrap().build().unwrap();
        let (hub, mut endpoints) = crate::coordinator::transport::LoopbackHub::new(2);
        // The dead worker takes the LOWER endpoint index: shutdown must
        // still reach the healthy worker behind it (broadcast is
        // best-effort, not fail-fast).
        let ep_good = endpoints.pop().unwrap();
        let ep_bad = endpoints.pop().unwrap();
        let mk = |client_id| crate::coordinator::worker::Worker {
            client_id,
            shard: vec![vec![1.0; d]],
            protocol: proto.clone(),
            update: mean_update(),
            seed: 3,
        };
        let good = mk(0);
        let bad = mk(1 << 40); // client id overflows the stream-id field
        let h_good = std::thread::spawn(move || good.run_loopback(ep_good));
        let h_bad = std::thread::spawn(move || bad.run_loopback(ep_bad));
        let mut leader = Leader::new(proto, Box::new(hub), 3);
        assert!(leader.round(0, d as u32, &[]).is_err(), "round must error, not hang");
        // The dead worker's endpoint is gone, so shutdown may only reach
        // the surviving worker — best effort is all that is required.
        let _ = leader.shutdown();
        assert!(h_good.join().unwrap().is_ok());
        assert!(h_bad.join().unwrap().is_err());
    }

    #[test]
    fn weighted_slots_aggregate_correctly() {
        // Two workers, one slot, weights 1 and 3: mean = (1*a + 3*b)/4.
        let d = 8;
        let proto = ProtocolConfig::parse("float32", d).unwrap().build().unwrap();
        let update: super::super::worker::UpdateFn = Arc::new(move |_b, _dim, shard| {
            let w = shard[0][0]; // smuggle the weight via the shard
            vec![(vec![w; 8], w)]
        });
        let shards = vec![vec![vec![1.0f32; d]], vec![vec![3.0f32; d]]];
        let (mut leader, handles) =
            spawn_local_cluster(proto, shards, update, 7);
        let out = leader.round(0, d as u32, &[]).unwrap();
        let expect = (1.0 * 1.0 + 3.0 * 3.0) / 4.0;
        for &v in &out.means[0] {
            assert!((v - expect).abs() < 1e-6, "{v} vs {expect}");
        }
        assert_eq!(out.weights[0], 4.0);
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn sampling_protocol_keeps_barrier() {
        // With p=0.5 some workers stay silent; the round must still finish
        // and remain unbiased thanks to Lemma 8 scaling.
        let d = 16;
        let n = 40;
        let shards: Vec<Vec<Vec<f32>>> = (0..n).map(|_| vec![vec![2.0; d]]).collect();
        let (mut leader, handles) = cluster("klevel:k=32,p=0.5", d, shards);
        let mut est_sum = vec![0.0f64; d];
        let rounds = 60;
        for r in 0..rounds {
            let out = leader.round(r, d as u32, &[]).unwrap();
            assert!(out.n_frames < n); // some silenced (overwhelmingly likely)
            for (s, &v) in est_sum.iter_mut().zip(&out.means[0]) {
                *s += v as f64;
            }
        }
        // Per-round std of each coordinate is 2·√((1−p)/(np)) ≈ 0.32;
        // over 60 rounds the mean's std is ≈ 0.041 — allow ~6σ.
        for &s in &est_sum {
            let mean = s / rounds as f64;
            assert!((mean - 2.0).abs() < 0.25, "mean {mean} vs 2.0");
        }
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }
}
