//! The leader (server) side of the coordinator: drives rounds, enforces
//! the barrier, and aggregates per-slot weighted means through a
//! **streaming, parallel decode pipeline** that also understands
//! pre-merged spans from the aggregation tier.
//!
//! # Streaming aggregation
//!
//! Each upload is handed to a decode pool the moment it arrives — each
//! pool thread folds it straight into its own per-slot state
//! ([`SpanAccum::fold_frames`], reusing one scratch accumulator across
//! frames, zero allocation per frame) — so decode work overlaps the
//! barrier wait; at the barrier the per-thread states are absorbed.
//! The batch equivalents ([`decode_upload`] + [`merge_decoded`])
//! remain as the allocating two-phase path for simulators and tests.
//! A child may equally be an aggregation-tier node
//! (see `coordinator::aggregator`) sending a `PartialUpload` — already
//! decoded and merged for its whole client span — which the barrier
//! absorbs directly, mixing plain and pre-merged children freely.
//!
//! # Determinism
//!
//! The per-slot fold state is exact (fixed-point integer sums, see
//! `protocol::exact`), so merging is associative and commutative: the
//! outcome is **bit-identical for every arrival order, decode-thread
//! count, and aggregation-tree shape**, and equals the flat sequential
//! specification [`aggregate_uploads_reference`]. The conformance
//! suites in `tests/streaming_leader.rs` and
//! `tests/tree_aggregation.rs` prove this across every protocol spec.
//!
//! # Barrier liveness
//!
//! By default the barrier waits forever — the right behavior for
//! in-process loopback clusters, where a dead worker already wakes the
//! barrier explicitly. For TCP deployments, [`Leader::with_round_timeout`]
//! arms a deadline; an expired round fails with an error that names the
//! missing children instead of hanging.
//!
//! # Partial rounds (Lemma 8)
//!
//! [`BarrierPolicy::Partial`] turns an expired deadline from an error
//! into an *estimate*: the round finalizes from the surviving client
//! set S. The paper's Lemma 8 analyzes exactly this — uniform client
//! sampling at rate p wraps any protocol π into π_p with
//! `E(π_p, X) = E(π, X)/p + (1−p)/(n·p) · (Σ‖Xᵢ‖² / n)` and cost
//! `C(π_p) = p · C(π)` — with the estimator dividing the surviving sum
//! by the sampling divisor `n·p`. Instantiated at the *observed* rate
//! p̂ = |S|/n, that divisor is `n·p̂ = |S|`, and the exact fold
//! produces it for free: every slot's `holders` counter counts the
//! clients whose contribution reached the fold (including silent
//! sampled-out frames), so in a partial round `holders = |S|` and the
//! plain-mean finish divides by precisely the Lemma 8 divisor at p̂ —
//! bit-for-bit the `protocol::sampling` wrapper's estimate for the
//! same surviving set (conformance-tested in
//! `tests/partial_rounds.rs`). Weighted (non-uniform)
//! slots divide by the survivors' exact weight sum — the natural
//! weighted extension of the same estimator. Each round's p̂ is
//! recorded in [`RoundMetrics::participation`] for the rate
//! controller, which re-ranks its frontier under the same
//! sampling-wrapper MSE model (`rate::model`).

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use super::metrics::{ExperimentMetrics, RoundMetrics};
use super::transport::{Message, TransportHub, WeightedFrame, WireError, ROOT_SESSION};
use crate::protocol::config::ProtocolConfig;
use crate::protocol::{Accumulator, Protocol, RoundCtx, RoundState, SlotPartial};

/// Result of one coordinated round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// Aggregated mean per slot (slot = position in each worker's upload,
    /// e.g. cluster index for Lloyd's; one slot for plain mean estimation).
    pub means: Vec<Vec<f32>>,
    /// Total weight per slot.
    pub weights: Vec<f64>,
    /// Exact uplink payload bits this round (sum of frame bit lengths,
    /// counted at the client edge even when forwarded through aggregators).
    pub uplink_bits: u64,
    /// Number of non-silent frames received.
    pub n_frames: usize,
}

/// Identity of one direct child of a barrier node: a worker, or an
/// aggregation-tier node covering a client span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChildKey {
    Client(u64),
    Aggregator { id: u64, span: (u64, u64) },
}

impl ChildKey {
    /// Client span the child speaks for.
    pub fn span(&self) -> (u64, u64) {
        match self {
            ChildKey::Client(c) => (*c, c.saturating_add(1)),
            ChildKey::Aggregator { span, .. } => *span,
        }
    }
}

impl std::fmt::Display for ChildKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChildKey::Client(c) => write!(f, "client {c}"),
            ChildKey::Aggregator { id, span } => {
                write!(f, "aggregator {id} [{}..{})", span.0, span.1)
            }
        }
    }
}

/// One child's contribution with every slot decoded into a
/// [`SlotPartial`]: the unit of work of the streaming pipeline. For a
/// worker upload, producing it is the expensive half of server-side
/// aggregation (bit unpacking + dequantization, on any decode thread);
/// for an aggregation-tier child it arrives in this form on the wire.
pub struct DecodedUpload {
    /// Who this came from (also the span used for ordering/diagnostics).
    pub origin: ChildKey,
    /// One entry per slot. `None` is a silent (sampled-out) frame: it
    /// counts as one slot holder and contributes nothing else, so it
    /// carries no dense state — under heavy sampling most frames are
    /// silent, and a dim-sized zero partial per silent frame would
    /// dominate the barrier's memory.
    pub slots: Vec<Option<SlotPartial>>,
    /// Sum of the non-silent frames' bit lengths at the client edge.
    pub uplink_bits: u64,
    /// Non-silent frame count.
    pub n_frames: usize,
}

/// Decode one worker's upload into per-slot partials. Shares only the
/// immutable round state, so uploads decode concurrently on any threads,
/// in any arrival order, without affecting the merged bits.
pub fn decode_upload(
    proto: &dyn Protocol,
    state: &RoundState,
    client: u64,
    frames: &[WeightedFrame],
) -> Result<DecodedUpload> {
    let mut slots = Vec::with_capacity(frames.len());
    let mut uplink_bits = 0u64;
    let mut n_frames = 0usize;
    for wf in frames {
        if wf.frame.bit_len == 0 {
            slots.push(None);
        } else {
            uplink_bits += wf.frame.bit_len;
            n_frames += 1;
            slots.push(Some(SlotPartial::decode(proto, state, &wf.frame, wf.weight)?));
        }
    }
    Ok(DecodedUpload { origin: ChildKey::Client(client), slots, uplink_bits, n_frames })
}

/// Running slot-wise fold of decoded children: one [`SlotPartial`] per
/// slot plus the span's client-edge accounting, growing only with the
/// slot count — never with the child count. This is what each decode
/// thread (and the barrier thread) accumulates into *eagerly*, the
/// moment a child decodes, so the streaming barrier retains
/// O(threads · slots · dim) state instead of one decoded upload per
/// child (O(n · dim) at a flat leader — the PR-4 peak-memory item).
///
/// Because every per-slot state is an exact fixed-point sum, folding
/// child-by-child here is bit-identical to the batch slot-by-slot fold
/// ([`fold_spans`]) for any grouping and order.
pub struct SpanAccum {
    dim: usize,
    slots: Vec<SlotPartial>,
    uplink_bits: u64,
    n_frames: u64,
}

impl SpanAccum {
    /// An empty accumulator for a protocol of internal dimension `dim`.
    pub fn new(dim: usize) -> Self {
        SpanAccum { dim, slots: Vec::new(), uplink_bits: 0, n_frames: 0 }
    }

    /// Fold one decoded child in: exact merge per present slot, holder
    /// count only for silent slots, counters summed. Slots grow to the
    /// widest child seen so far (ragged uploads contribute nothing to
    /// the slots they lack, exactly like the batch fold).
    pub fn fold(&mut self, d: &DecodedUpload) -> Result<()> {
        while self.slots.len() < d.slots.len() {
            self.slots.push(SlotPartial::empty(self.dim));
        }
        for (acc, s) in self.slots.iter_mut().zip(&d.slots) {
            match s {
                Some(p) => acc.merge(p)?,
                // Bit-identical to merging a dense silent partial: zeros
                // add nothing, so only the holder count moves.
                None => acc.add_silent_holder(),
            }
        }
        self.uplink_bits += d.uplink_bits;
        self.n_frames += d.n_frames as u64;
        Ok(())
    }

    /// Decode one worker upload straight into this accumulator, slot by
    /// slot, through the carry-save fold and a caller-owned scratch
    /// accumulator: bit-identical to `fold(&decode_upload(...)?)` (the
    /// per-slot fold is exact, so streaming frames in cannot change the
    /// bits) with zero per-frame allocation — the decode pool's hot
    /// path. On error the round is abandoned, so no rollback is needed.
    pub fn fold_frames(
        &mut self,
        proto: &dyn Protocol,
        state: &RoundState,
        frames: &[WeightedFrame],
        scratch: &mut Accumulator,
    ) -> Result<()> {
        while self.slots.len() < frames.len() {
            self.slots.push(SlotPartial::empty(self.dim));
        }
        for (slot, wf) in self.slots.iter_mut().zip(frames) {
            if wf.frame.bit_len == 0 {
                slot.add_silent_holder();
            } else {
                self.uplink_bits += wf.frame.bit_len;
                self.n_frames += 1;
                slot.fold_frame(proto, state, &wf.frame, wf.weight, scratch)?;
            }
        }
        Ok(())
    }

    /// Merge another accumulator in (the cross-thread reduction at the
    /// barrier). Exact, so the thread assignment of children and the
    /// order of absorption cannot change a bit of the result.
    pub fn absorb(&mut self, other: SpanAccum) -> Result<()> {
        while self.slots.len() < other.slots.len() {
            self.slots.push(SlotPartial::empty(self.dim));
        }
        for (acc, s) in self.slots.iter_mut().zip(&other.slots) {
            acc.merge(s)?;
        }
        self.uplink_bits += other.uplink_bits;
        self.n_frames += other.n_frames;
        Ok(())
    }

    /// Sum of the folded children's client-edge payload bits.
    pub fn uplink_bits(&self) -> u64 {
        self.uplink_bits
    }

    /// Sum of the folded children's non-silent frame counts.
    pub fn n_frames(&self) -> u64 {
        self.n_frames
    }

    /// Maximum per-slot holder count across the fold — the number of
    /// clients whose contribution (including silent, sampled-out frames)
    /// reached this accumulator. In a partial round this is |S|, the
    /// numerator of the observed participation rate p̂ = |S| / n:
    /// aggregation-tier `PartialUpload`s carry their surviving holder
    /// counts transparently, so the root reads true survivor totals even
    /// through a tree. 0 when nothing folded yet.
    pub fn max_holders(&self) -> u64 {
        self.slots.iter().map(|s| s.holders).max().unwrap_or(0)
    }

    /// The merged per-slot partials (what an aggregation-tier node
    /// forwards upstream).
    pub fn into_slots(self) -> Vec<SlotPartial> {
        self.slots
    }

    /// Absorb a set of per-shard accumulators — independent exact folds
    /// of disjoint coordinate ranges over the *same* children — by
    /// concatenating each slot's shard slices back to full dimension
    /// ([`SlotPartial::concat_shards`]) and merging the result in. The
    /// ranges must partition `[0, dim)` and every shard must agree on
    /// the fold counters, or the absorb errors out. Bit-identical to
    /// having folded the same children unsharded: concatenation moves
    /// exact per-coordinate sums, never rounds.
    pub fn absorb_sharded(&mut self, shards: &mut [((u32, u32), SpanAccum)]) -> Result<()> {
        if shards.is_empty() {
            return Ok(());
        }
        // Pad every shard to the widest slot count seen: a missing slot
        // is the empty partial, exactly as in the unsharded fold (the
        // counter-equality check in concat then enforces that the
        // shards really saw the same children).
        let n_slots = shards.iter().map(|(_, a)| a.slots.len()).max().unwrap_or(0);
        for (range, acc) in shards.iter_mut() {
            while acc.slots.len() < n_slots {
                acc.slots.push(SlotPartial::empty((range.1 - range.0) as usize));
            }
        }
        while self.slots.len() < n_slots {
            self.slots.push(SlotPartial::empty(self.dim));
        }
        for slot in 0..n_slots {
            let parts: Vec<((u32, u32), &SlotPartial)> =
                shards.iter().map(|(r, a)| (*r, &a.slots[slot])).collect();
            let full = SlotPartial::concat_shards(&parts, self.dim)?;
            self.slots[slot].merge(&full)?;
        }
        for (_, acc) in shards.iter() {
            self.uplink_bits += acc.uplink_bits;
            self.n_frames += acc.n_frames;
        }
        Ok(())
    }

    /// Finish every slot at the root (single rounding + protocol
    /// postprocessing) into the round outcome.
    pub fn finish(&self, proto: &dyn Protocol, state: &RoundState) -> RoundOutcome {
        let mut means = Vec::with_capacity(self.slots.len());
        let mut weights = Vec::with_capacity(self.slots.len());
        for sp in &self.slots {
            let (mean, weight) = sp.finish(proto, state);
            means.push(mean);
            weights.push(weight);
        }
        RoundOutcome {
            means,
            weights,
            uplink_bits: self.uplink_bits,
            n_frames: self.n_frames as usize,
        }
    }
}

/// Merge decoded children slot-wise into one [`SlotPartial`] per slot —
/// the aggregation-tier node's whole job, and the first half of the
/// leader's. Exact (associative and commutative), so the result is
/// independent of arrival order and of how the children were grouped
/// into spans (any tree ≡ flat) — no sorting needed.
pub fn fold_spans(proto: &dyn Protocol, decoded: &[DecodedUpload]) -> Result<Vec<SlotPartial>> {
    let mut acc = SpanAccum::new(proto.internal_dim());
    for d in decoded {
        acc.fold(d)?;
    }
    Ok(acc.into_slots())
}

/// Merge decoded children into the round outcome: fold every slot, then
/// finish each one (single rounding + protocol postprocessing).
pub fn merge_decoded(
    proto: &dyn Protocol,
    state: &RoundState,
    decoded: Vec<DecodedUpload>,
) -> Result<RoundOutcome> {
    let mut acc = SpanAccum::new(proto.internal_dim());
    for d in &decoded {
        acc.fold(d)?;
    }
    Ok(acc.finish(proto, state))
}

/// The flat sequential aggregation path: sort uploads by client id, then
/// decode and fold every slot in that order, one frame at a time, on one
/// thread. Retained as the executable specification of what the
/// streaming pipeline — and any aggregation tree — must produce; the
/// conformance suites diff against it.
pub fn aggregate_uploads_reference(
    proto: &dyn Protocol,
    state: &RoundState,
    mut uploads: Vec<(u64, Vec<WeightedFrame>)>,
) -> Result<RoundOutcome> {
    uploads.sort_by_key(|(client, _)| *client);
    let dim = proto.internal_dim();
    let n_slots = uploads.iter().map(|(_, f)| f.len()).max().unwrap_or(0);
    let mut means = Vec::with_capacity(n_slots);
    let mut weights = Vec::with_capacity(n_slots);
    let mut uplink_bits = 0u64;
    let mut n_frames = 0usize;
    for slot in 0..n_slots {
        let mut acc = SlotPartial::empty(dim);
        for (_, frames) in &uploads {
            let Some(wf) = frames.get(slot) else { continue };
            if wf.frame.bit_len == 0 {
                acc.add_silent_holder();
            } else {
                uplink_bits += wf.frame.bit_len;
                n_frames += 1;
                acc.merge(&SlotPartial::decode(proto, state, &wf.frame, wf.weight)?)?;
            }
        }
        let (mean, weight) = acc.finish(proto, state);
        means.push(mean);
        weights.push(weight);
    }
    Ok(RoundOutcome { means, weights, uplink_bits, n_frames })
}

/// Decode a batch of already-received uploads on `decode_threads`
/// workers (the pool half of [`aggregate_uploads_streaming`], shared
/// with the in-memory tree simulator).
pub(crate) fn decode_all(
    proto: &dyn Protocol,
    state: &RoundState,
    uploads: &[(u64, Vec<WeightedFrame>)],
    decode_threads: usize,
) -> Result<Vec<DecodedUpload>> {
    if decode_threads <= 1 {
        return uploads.iter().map(|(c, f)| decode_upload(proto, state, *c, f)).collect();
    }
    let next = AtomicUsize::new(0);
    let next = &next;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..decode_threads)
            .map(|_| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= uploads.len() {
                            break;
                        }
                        let (c, f) = &uploads[i];
                        out.push(decode_upload(proto, state, *c, f));
                    }
                    out
                })
            })
            .collect();
        let mut all = Vec::with_capacity(uploads.len());
        for h in handles {
            for r in h.join().expect("decode thread panicked") {
                all.push(r?);
            }
        }
        Ok(all)
    })
}

/// Run the streaming aggregation over an already-received upload list
/// with `decode_threads` workers. Shares the exact-merge core with
/// [`Leader::round`]; only the task scheduling differs (a ready list
/// here vs the channel-fed pool a live round streams through). Exposed
/// for benches and the conformance suite.
pub fn aggregate_uploads_streaming(
    proto: &dyn Protocol,
    state: &RoundState,
    uploads: &[(u64, Vec<WeightedFrame>)],
    decode_threads: usize,
) -> Result<RoundOutcome> {
    let decoded = decode_all(proto, state, uploads, decode_threads)?;
    merge_decoded(proto, state, decoded)
}

/// What one barrier pass over a hub produced: the eagerly folded
/// per-slot state plus the wait/decode time split. Individual children's
/// decoded uploads are *not* retained — each one folds into a per-thread
/// [`SpanAccum`] the moment it decodes and is dropped, so the barrier's
/// peak memory is O(threads · slots · dim), not O(children · dim).
pub(crate) struct CollectedRound {
    pub folded: SpanAccum,
    /// The children that answered, in arrival order.
    pub seen: Vec<ChildKey>,
    pub wait_wall: Duration,
    pub decode_wall: Duration,
    /// Current-round uploads from clients the barrier had already
    /// counted — dropped, never folded twice.
    pub duplicate_uploads: u64,
    /// True when the barrier deadline expired and
    /// [`BarrierPolicy::Partial`] finalized the round from the children
    /// that had answered.
    pub timed_out: bool,
}

/// What the barrier does when its deadline expires with children still
/// missing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BarrierPolicy {
    /// A timed-out round is an error ([`BarrierTimeout`]) naming the
    /// missing children; nothing is estimated. The pre-scenario
    /// behavior, and still the default.
    #[default]
    Strict,
    /// Finalize the round from the children that did answer. The exact
    /// fold's per-slot holder counts then equal |S|, the survivor
    /// count, so the plain-mean finish divides by n·p̂ instead of n —
    /// precisely the Lemma 8 client-sampling estimator at the observed
    /// participation rate p̂ = |S| / n (see the module docs). A round
    /// in which *no* child answered still errors with
    /// [`BarrierTimeout`]: there is nothing to rescale.
    Partial,
}

/// Marker at the root of every barrier-timeout error chain, so callers
/// (the aggregator loop) can tell a survivable timeout from a fatal
/// error without string matching.
#[derive(Debug)]
pub(crate) struct BarrierTimeout;

impl std::fmt::Display for BarrierTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "round barrier timed out")
    }
}

impl std::error::Error for BarrierTimeout {}

fn barrier_timeout_error(
    round: u64,
    timeout: Duration,
    seen: &[ChildKey],
    expected: &[ChildKey],
    n_children: usize,
) -> anyhow::Error {
    let missing: Vec<String> =
        expected.iter().filter(|k| !seen.contains(k)).map(|k| k.to_string()).collect();
    let msg = if missing.is_empty() {
        // No usable expectation list: name who DID answer.
        let got: Vec<String> = seen.iter().map(|k| k.to_string()).collect();
        format!(
            "round {round} barrier timed out after {timeout:?}: {}/{n_children} children \
             answered ({}); the remaining children are unidentified ({})",
            seen.len(),
            if got.is_empty() { "none".to_string() } else { got.join(", ") },
            if expected.is_empty() {
                "no expectation list"
            } else {
                "the expectation list is stale"
            },
        )
    } else {
        format!(
            "round {round} barrier timed out after {timeout:?}: missing {} of {n_children} \
             children: {}",
            missing.len(),
            missing.join(", "),
        )
    };
    anyhow::Error::new(BarrierTimeout).context(msg)
}

/// Children must speak for disjoint client spans — a duplicate client id
/// or an overlapping aggregator span is a miswired topology, caught at
/// the barrier rather than silently double-counted. Under dimension
/// sharding the check is **per shard range**: siblings folding disjoint
/// coordinate slices legitimately cover the same clients, so each child
/// carries the range it folded and only children inside the same range
/// (plus full-dimension children, which overlap every range) must be
/// span-disjoint.
fn check_disjoint_spans(children: &[((u32, u32), ChildKey)], full: (u32, u32)) -> Result<()> {
    let mut ranges: Vec<(u32, u32)> = children.iter().map(|&(r, _)| r).collect();
    ranges.sort_unstable();
    ranges.dedup();
    for &range in &ranges {
        let mut spans: Vec<(u64, u64, ChildKey)> = children
            .iter()
            .filter(|&&(r, _)| r == range || r == full)
            .map(|&(_, k)| (k.span().0, k.span().1, k))
            .collect();
        spans.sort_by_key(|&(lo, hi, _)| (lo, hi));
        for w in spans.windows(2) {
            ensure!(
                w[0].1 <= w[1].0,
                "children cover overlapping client spans in shard [{}, {}): {} and {}",
                range.0,
                range.1,
                w[0].2,
                w[1].2
            );
        }
    }
    Ok(())
}

/// One barrier pass: broadcast already done, receive exactly one message
/// per child, streaming worker uploads through a decode pool and
/// absorbing aggregation-tier `PartialUpload`s directly. Shared by
/// [`Leader::round`] and the aggregation-tier node loop.
///
/// `session` is the wire session this barrier belongs to: every
/// envelope must carry it, and one that does not is a **typed**
/// [`WireError::UnknownSession`] rejection — under session multiplexing
/// a stray tenant's message is a routing bug to surface, never a frame
/// to silently drop.
///
/// Dimension-sharded children (a `PartialUpload` whose shard range is a
/// strict slice of the internal dimension) fold into one accumulator
/// per range; at the barrier the ranges are concatenated back to full
/// dimension ([`SpanAccum::absorb_sharded`]) — bit-identical to the
/// unsharded fold.
///
/// `n_msgs` is how many messages close the barrier. It equals the child
/// connection count except under dimension sharding, where a sharded
/// child sends one `PartialUpload` per shard range over its single
/// connection.
#[allow(clippy::too_many_arguments)]
pub(crate) fn collect_round(
    hub: &mut dyn TransportHub,
    proto: &dyn Protocol,
    round_state: &RoundState,
    session: u16,
    round: u64,
    decode_threads: usize,
    timeout: Option<Duration>,
    expected: &[ChildKey],
    n_msgs: usize,
    policy: BarrierPolicy,
) -> Result<CollectedRound> {
    let n_children = n_msgs;
    ensure!(n_children > 0, "no children connected");
    let decode_threads = decode_threads.clamp(1, n_children);
    let decode_ns = AtomicU64::new(0);
    let mut wait_wall = Duration::ZERO;
    let mut duplicate_uploads = 0u64;
    let mut timed_out = false;
    let mut seen: Vec<ChildKey> = Vec::with_capacity(n_children);
    // Each child paired with the shard range it folded (workers cover
    // the full dimension) — the unit of the span-disjointness check.
    let mut ranged: Vec<((u32, u32), ChildKey)> = Vec::with_capacity(n_children);
    // Duplicate detection stays O(1) per arrival; `seen` keeps arrival
    // order for diagnostics.
    let mut seen_clients: HashSet<u64> = HashSet::with_capacity(n_children);
    // Keyed by (agg_id, shard): one node legitimately answers once per
    // shard range, but twice for the same range is a duplicate.
    let mut seen_aggs: HashSet<(u64, (u32, u32))> = HashSet::new();
    let deadline = timeout.map(|t| Instant::now() + t);

    // Streaming barrier: this thread owns the transport and hands each
    // worker upload to the decode pool the moment it arrives, so
    // decoding overlaps the wait for slower children. Each pool thread
    // folds what it decodes into its own `SpanAccum` immediately (the
    // exact merge makes the thread assignment invisible in the bits) and
    // sends back one accumulator at drain time. The channels live
    // outside the scope: scoped threads may only borrow data that
    // outlives the scope itself.
    let internal_dim = proto.internal_dim();
    let full_range = (0u32, internal_dim as u32);
    let (task_tx, task_rx) = mpsc::channel::<(u64, Vec<WeightedFrame>)>();
    let (out_tx, out_rx) = mpsc::channel::<Result<SpanAccum>>();
    let task_rx = Mutex::new(task_rx);
    let folded = std::thread::scope(|scope| -> Result<SpanAccum> {
        // The decode pool spawns lazily on the first worker upload: a
        // barrier whose children are all aggregation-tier nodes absorbs
        // `PartialUpload`s directly and never pays for idle threads.
        let mut pool_started = false;
        let mut n_pool_threads = 0usize;

        // Barrier: exactly one message per child. With a deadline armed,
        // messages answering an *earlier* round are dropped, not errors:
        // they are late replies to a round that already timed out, and
        // dropping them is what lets the round that superseded it still
        // complete. Without a deadline no round can have timed out, so a
        // stale answer is a protocol violation worth failing fast on.
        let mut main_acc = SpanAccum::new(internal_dim);
        // One accumulator per strict shard range seen this round,
        // concatenated back to full dimension at the barrier.
        let mut shard_accs: Vec<((u32, u32), SpanAccum)> = Vec::new();
        let mut n_accepted = 0usize;
        while n_accepted < n_children {
            let t = Instant::now();
            let env = match deadline {
                None => hub.recv_env()?,
                Some(dl) => {
                    let remain = dl.checked_duration_since(Instant::now());
                    let env = match remain {
                        None => None,
                        Some(remain) => hub.recv_env_timeout(remain)?,
                    };
                    match env {
                        Some(e) => e,
                        None => {
                            // Partial policy: if anyone answered, close
                            // the barrier on the survivors and finalize
                            // — the drain below folds exactly what was
                            // accepted, and the holder counts carry |S|
                            // (the Lemma 8 rescale) into the finish. An
                            // empty round still errors: nothing to
                            // rescale, and the flap path (aggregator
                            // skip-and-recover) depends on the typed
                            // [`BarrierTimeout`].
                            if policy == BarrierPolicy::Partial && n_accepted > 0 {
                                timed_out = true;
                                break;
                            }
                            return Err(barrier_timeout_error(
                                round,
                                timeout.unwrap_or_default(),
                                &seen,
                                expected,
                                n_children,
                            ));
                        }
                    }
                }
            };
            wait_wall += t.elapsed();
            if env.session != session {
                return Err(WireError::UnknownSession(env.session).into());
            }
            match env.msg {
                Message::Upload { client, round: r, frames } => {
                    if r < round && timeout.is_some() {
                        continue; // late answer to a timed-out round
                    }
                    ensure!(r == round, "client {client} answered round {r}, expected {round}");
                    if !seen_clients.insert(client) {
                        // With a deadline armed, a client may legitimately
                        // answer twice: its first answer raced the previous
                        // round's timeout, or a reconnect re-sent the
                        // current round. The barrier already counted this
                        // client, so fold the first copy only and account
                        // for the drop. Without a deadline a duplicate is
                        // a protocol violation worth failing fast on.
                        ensure!(timeout.is_some(), "duplicate upload from client {client}");
                        duplicate_uploads += 1;
                        continue;
                    }
                    seen.push(ChildKey::Client(client));
                    ranged.push((full_range, ChildKey::Client(client)));
                    if !pool_started {
                        pool_started = true;
                        n_pool_threads = decode_threads;
                        for i in 0..decode_threads {
                            let out_tx = out_tx.clone();
                            let task_rx = &task_rx;
                            let decode_ns = &decode_ns;
                            std::thread::Builder::new()
                                .name(format!("dme-decode-{i}"))
                                .spawn_scoped(scope, move || {
                                    // Eager fold: each upload decodes
                                    // straight into this thread's
                                    // accumulator through a recycled
                                    // scratch — nothing per-child is
                                    // allocated or retained.
                                    let mut acc = SpanAccum::new(internal_dim);
                                    let mut scratch = proto.new_accumulator();
                                    loop {
                                        // Hold the lock only for the
                                        // dequeue, not the decode, so the
                                        // pool drains in parallel.
                                        let task = task_rx.lock().unwrap().recv();
                                        let Ok((_client, frames)) = task else { break };
                                        let t = Instant::now();
                                        let res = acc.fold_frames(
                                            proto,
                                            round_state,
                                            &frames,
                                            &mut scratch,
                                        );
                                        decode_ns.fetch_add(
                                            t.elapsed().as_nanos() as u64,
                                            Ordering::Relaxed,
                                        );
                                        if let Err(e) = res {
                                            let _ = out_tx.send(Err(e));
                                            return;
                                        }
                                    }
                                    let _ = out_tx.send(Ok(acc));
                                })
                                .expect("spawning decode thread");
                        }
                    }
                    task_tx.send((client, frames)).expect("decode pool hung up");
                    n_accepted += 1;
                }
                Message::PartialUpload {
                    agg_id,
                    round: r,
                    span,
                    uplink_bits,
                    n_frames,
                    shard,
                    slots,
                } => {
                    if r < round && timeout.is_some() {
                        continue; // late answer to a timed-out round
                    }
                    ensure!(
                        r == round,
                        "aggregator {agg_id} answered round {r}, expected {round}"
                    );
                    ensure!(
                        seen_aggs.insert((agg_id, shard)),
                        "duplicate partial upload from aggregator {agg_id} for shard \
                         [{}, {})",
                        shard.0,
                        shard.1
                    );
                    ensure!(
                        shard.1 as usize <= internal_dim,
                        "aggregator {agg_id} shard [{}, {}) exceeds internal dimension \
                         {internal_dim}",
                        shard.0,
                        shard.1
                    );
                    let key = ChildKey::Aggregator { id: agg_id, span };
                    seen.push(key);
                    ranged.push((shard, key));
                    let d = DecodedUpload {
                        origin: key,
                        slots: slots.into_iter().map(Some).collect(),
                        uplink_bits,
                        n_frames: n_frames as usize,
                    };
                    if shard == full_range || d.slots.is_empty() {
                        // Full-dimension (or slotless, counters-only)
                        // spans fold straight into the barrier thread's
                        // accumulator — no decode pool involved.
                        main_acc.fold(&d)?;
                    } else {
                        // A strict dimension slice: fold into that
                        // range's own accumulator, concatenated back to
                        // full dimension once the barrier closes.
                        let width = (shard.1 - shard.0) as usize;
                        let pos = match shard_accs.iter().position(|(r, _)| *r == shard) {
                            Some(p) => p,
                            None => {
                                shard_accs.push((shard, SpanAccum::new(width)));
                                shard_accs.len() - 1
                            }
                        };
                        shard_accs[pos].1.fold(&d)?;
                    }
                    n_accepted += 1;
                }
                Message::RoundStart { .. } | Message::SpecChange { .. } | Message::Shutdown => {
                    bail!("unexpected message at the round barrier (did a child die mid-round?)")
                }
            }
        }
        drop(task_tx); // pool drains the queue, then exits
        drop(out_tx); // the pool threads hold the only other senders

        // Cross-thread reduction: absorb one accumulator per pool thread
        // (a thread that hit a decode error sends Err instead). The
        // merge is exact, so absorption order is invisible in the bits.
        for _ in 0..n_pool_threads {
            let acc = out_rx.recv().expect("decode pool died")?;
            main_acc.absorb(acc)?;
        }
        // Concatenate the shard-range folds back to full dimension and
        // merge them in (errors if the ranges fail to partition the
        // dimension or disagree on fold counters).
        main_acc.absorb_sharded(&mut shard_accs)?;
        Ok(main_acc)
    })?;

    check_disjoint_spans(&ranged, full_range)?;
    Ok(CollectedRound {
        folded,
        seen,
        wait_wall,
        decode_wall: Duration::from_nanos(decode_ns.load(Ordering::Relaxed)),
        duplicate_uploads,
        timed_out,
    })
}

/// The coordinator leader (tree root).
pub struct Leader {
    protocol: Arc<dyn Protocol>,
    hub: Box<dyn TransportHub>,
    seed: u64,
    /// Wire session every broadcast goes out on and every barrier
    /// envelope must carry — [`ROOT_SESSION`] unless this leader drives
    /// one tenant of a multiplexed deployment.
    session: u16,
    metrics: ExperimentMetrics,
    decode_threads: usize,
    round_timeout: Option<Duration>,
    /// Children expected at the barrier — seeded by the spawn helpers
    /// (or [`Leader::with_expected_children`]) and refreshed from each
    /// completed round, so a timeout can name exactly who is missing.
    expected_children: Vec<ChildKey>,
    /// Messages that close the barrier; defaults to the connection
    /// count. Dimension-sharded children send one `PartialUpload` per
    /// shard range over one connection, so a sharded tree sets this to
    /// `workers + aggregators × dim_shards`.
    barrier_msgs: Option<usize>,
    /// What a timed-out barrier does: error ([`BarrierPolicy::Strict`],
    /// the default) or finalize from the survivors with the Lemma 8
    /// participation rescale ([`BarrierPolicy::Partial`]).
    barrier_policy: BarrierPolicy,
}

impl Leader {
    pub fn new(protocol: Arc<dyn Protocol>, hub: Box<dyn TransportHub>, seed: u64) -> Self {
        Leader {
            protocol,
            hub,
            seed,
            session: ROOT_SESSION,
            metrics: ExperimentMetrics::default(),
            decode_threads: 1,
            round_timeout: None,
            expected_children: Vec::new(),
            barrier_msgs: None,
            barrier_policy: BarrierPolicy::default(),
        }
    }

    /// Choose the barrier's timeout behavior (builder style). Partial
    /// rounds require an armed [`Leader::with_round_timeout`] deadline
    /// to ever trigger; without one the barrier waits forever exactly as
    /// before.
    pub fn with_barrier_policy(mut self, policy: BarrierPolicy) -> Self {
        self.barrier_policy = policy;
        self
    }

    /// Change the barrier's timeout behavior on a live leader.
    pub fn set_barrier_policy(&mut self, policy: BarrierPolicy) {
        self.barrier_policy = policy;
    }

    /// Override how many messages close each round's barrier (builder
    /// style) — required when direct children are dimension-sharded and
    /// answer with one `PartialUpload` per shard range.
    pub fn with_barrier_messages(mut self, n: usize) -> Self {
        self.barrier_msgs = Some(n);
        self
    }

    /// Pin this leader to a wire session (builder style): broadcasts go
    /// out tagged `session`, and a barrier envelope on any other session
    /// is a typed [`WireError::UnknownSession`] rejection. The session
    /// id also feeds every worker's private stream derivation, so a
    /// tenant's estimates depend on `(session, seed, round, spec, data)`
    /// alone — solo and multiplexed runs of the same tenant agree bit
    /// for bit.
    pub fn with_session(mut self, session: u16) -> Self {
        self.session = session;
        self
    }

    /// The wire session this leader drives.
    pub fn session(&self) -> u16 {
        self.session
    }

    /// Set the decode-pool width (builder style). Any value produces
    /// bit-identical round outcomes — the merge is exact, so scheduling
    /// is free; `0` is treated as 1.
    pub fn with_decode_threads(mut self, n: usize) -> Self {
        self.decode_threads = n.max(1);
        self
    }

    /// Arm a per-round barrier deadline (builder style). The default —
    /// no timeout — waits forever, which keeps loopback behavior
    /// unchanged; with a timeout, a round whose children do not all
    /// answer in time fails with an error naming the missing ones. To
    /// recover, call [`Leader::round`] with the **next** round number:
    /// the barrier drops late answers to earlier rounds, while retrying
    /// the same number would race a child's late answer against its
    /// retry answer.
    pub fn with_round_timeout(mut self, timeout: Duration) -> Self {
        self.round_timeout = Some(timeout);
        self
    }

    /// Declare the identities of the children expected at the barrier
    /// (builder style) — used by timeout errors to name the missing.
    pub fn with_expected_children(mut self, children: Vec<ChildKey>) -> Self {
        self.expected_children = children;
        self
    }

    /// Change the decode-pool width on a live leader.
    pub fn set_decode_threads(&mut self, n: usize) {
        self.decode_threads = n.max(1);
    }

    /// Change or clear the barrier deadline on a live leader.
    pub fn set_round_timeout(&mut self, timeout: Option<Duration>) {
        self.round_timeout = timeout;
    }

    pub fn decode_threads(&self) -> usize {
        self.decode_threads
    }

    pub fn n_workers(&self) -> usize {
        self.hub.n_workers()
    }

    pub fn metrics(&self) -> &ExperimentMetrics {
        &self.metrics
    }

    /// Cumulative (downlink, uplink) transport bytes at the root hub.
    pub fn bytes_moved(&self) -> (u64, u64) {
        self.hub.bytes_moved()
    }

    /// Observed participation p̂ = |S| / n for a collected round. |S|
    /// comes from the fold's per-slot holder counts — aggregation-tier
    /// `PartialUpload`s carry their surviving holder totals, so the
    /// number is honest through a tree even when an aggregator answered
    /// for only part of its span. n is the expected-children span
    /// width (the enrolled population), falling back to the hub's
    /// connection count when no expectation list was ever seeded.
    fn participation_of(&self, collected: &CollectedRound) -> f64 {
        let mut num = collected.folded.max_holders();
        if num == 0 {
            // Counters-only edge (zero-slot uploads): fall back to the
            // client-span coverage of whoever answered.
            num = collected.seen.iter().map(|k| k.span().1 - k.span().0).sum();
        }
        let denom: u64 = self.expected_children.iter().map(|k| k.span().1 - k.span().0).sum();
        let denom = if denom > 0 { denom } else { self.hub.n_workers() as u64 };
        if denom == 0 {
            1.0
        } else {
            (num as f64 / denom as f64).min(1.0)
        }
    }

    /// Run one synchronous round: broadcast `state` (`n_slots × dim`
    /// flattened — what the workers need to compute their updates), then
    /// stream uploads through the decode pool as they arrive and merge
    /// at the barrier. Children may be workers, aggregation-tier nodes,
    /// or a mix.
    pub fn round(&mut self, round: u64, dim: u32, state: &[f32]) -> Result<RoundOutcome> {
        let t0 = Instant::now();
        ensure!(self.hub.n_workers() > 0, "no workers connected");
        // The payload is Arc-shared: one allocation for the whole
        // broadcast instead of one clone per worker. The leader's seed is
        // broadcast as the round's `shared_seed` — the shared-randomness
        // handshake: children derive the rotation and correlated rounding
        // offsets from the wire, not from local configuration.
        let bcast = self.hub.broadcast_session(
            self.session,
            &Message::RoundStart { round, shared_seed: self.seed, dim, payload: Arc::from(state) },
        );
        if let Err(e) = bcast {
            // Every hub stages the message to its live children before
            // surfacing dead ones, so under the partial policy a failed
            // broadcast just means some children have left — exactly the
            // situation the partial barrier finalizes around. (If *all*
            // children are gone, the barrier's receive fails and the
            // round errors as before.)
            if self.barrier_policy == BarrierPolicy::Partial {
                eprintln!("[leader] round {round}: broadcast saw departed children ({e:#})");
            } else {
                return Err(e);
            }
        }

        let ctx = RoundCtx::new(round, self.seed);
        let proto = self.protocol.clone();
        // One round session: shared state (the rotation for π_srk) is
        // prepared once and reused by every decode thread and the merge.
        let round_state = proto.prepare(&ctx);
        let expected = std::mem::take(&mut self.expected_children);
        let n_msgs = self.barrier_msgs.unwrap_or_else(|| self.hub.n_workers());
        let collected = collect_round(
            self.hub.as_mut(),
            proto.as_ref(),
            &round_state,
            self.session,
            round,
            self.decode_threads,
            self.round_timeout,
            &expected,
            n_msgs,
            self.barrier_policy,
        );
        let collected = match collected {
            Ok(c) => c,
            Err(e) => {
                // Keep the expectation list so a retry's timeout error can
                // still name the missing children. Recovery must use the
                // NEXT round number: the barrier drops late answers to
                // earlier rounds, but re-running the *same* round races a
                // child's late first answer against its retry answer —
                // an unavoidable duplicate.
                self.expected_children = expected;
                return Err(e);
            }
        };
        match self.barrier_policy {
            BarrierPolicy::Strict => self.expected_children = collected.seen.clone(),
            BarrierPolicy::Partial => {
                // Union, never replacement: a child missing from a
                // partial round stays expected (it may recover next
                // round), and the participation denominator stays the
                // enrolled population rather than shrinking to whoever
                // answered last.
                let mut expected = expected;
                for k in &collected.seen {
                    if !expected.contains(k) {
                        expected.push(*k);
                    }
                }
                self.expected_children = expected;
            }
        }
        let participation = self.participation_of(&collected);

        let t_merge = Instant::now();
        let outcome = collected.folded.finish(proto.as_ref(), &round_state);
        let decode_wall = collected.decode_wall + t_merge.elapsed();

        let (down, up) = self.hub.bytes_moved();
        self.metrics.push(RoundMetrics {
            round,
            uplink_bits: outcome.uplink_bits,
            n_frames: outcome.n_frames,
            wall: t0.elapsed(),
            wait_wall: collected.wait_wall,
            decode_wall,
            cum_down_bytes: down,
            cum_up_bytes: up,
            participation,
            duplicate_uploads: collected.duplicate_uploads,
        });
        Ok(outcome)
    }

    /// The active protocol's display name.
    pub fn protocol_name(&self) -> String {
        self.protocol.name()
    }

    /// Switch the session's protocol to `spec` (the `ProtocolConfig`
    /// grammar string) starting at round `effective_round` — the round
    /// number of the *next* [`Leader::round`] call. The spec is built
    /// locally first (so an invalid spec errors without touching the
    /// tree), then broadcast as a tag-5 `SpecChange` that every worker
    /// and aggregator applies on receipt; transports are FIFO, so the
    /// switch is ordered before the next `RoundStart` on every link.
    ///
    /// Estimates after the switch are **bit-identical to a fresh session
    /// started at `spec`** and driven through the same round numbers:
    /// every bit of a round depends only on `(seed, round, client_id,
    /// spec, data)`, and the rebuild carries no state across specs
    /// (conformance-tested in `tests/rate_control.rs`, flat and tree,
    /// loopback and TCP).
    pub fn switch_spec(&mut self, spec: &str, effective_round: u64) -> Result<()> {
        let dim = self.protocol.dim();
        let proto = ProtocolConfig::parse(spec, dim)?.build()?;
        self.hub.broadcast_session(
            self.session,
            &Message::SpecChange { round: effective_round, spec: spec.to_string() },
        )?;
        self.protocol = proto;
        self.metrics.note_spec_change(effective_round, spec);
        Ok(())
    }

    /// Broadcast shutdown to all children (aggregators forward it down).
    pub fn shutdown(&mut self) -> Result<()> {
        self.hub.broadcast_session(self.session, &Message::Shutdown)
    }
}

/// Spawn `shards.len()` loopback worker threads plus a leader — the
/// flat single-process cluster used by examples, tests, and benches.
/// For a tree-shaped sibling see `coordinator::aggregator::spawn_local_tree`.
pub fn spawn_local_cluster(
    protocol: Arc<dyn Protocol>,
    shards: Vec<Vec<Vec<f32>>>,
    update: super::worker::UpdateFn,
    seed: u64,
) -> (Leader, Vec<std::thread::JoinHandle<Result<()>>>) {
    let n = shards.len();
    let (hub, endpoints) = super::transport::LoopbackHub::new(n);
    let mut handles = Vec::with_capacity(n);
    for (i, (shard, ep)) in shards.into_iter().zip(endpoints).enumerate() {
        let worker = super::worker::Worker {
            client_id: i as u64,
            shard,
            protocol: protocol.clone(),
            update: update.clone(),
            seed,
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("dme-worker-{i}"))
                .spawn(move || worker.run_loopback(ep))
                .expect("spawning worker thread"),
        );
    }
    let leader = Leader::new(protocol, Box::new(hub), seed)
        .with_expected_children((0..n as u64).map(ChildKey::Client).collect());
    (leader, handles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::mean_update;
    use crate::protocol::config::ProtocolConfig;
    use crate::protocol::Encoder;
    use crate::stats;

    fn cluster(
        spec: &str,
        d: usize,
        shards: Vec<Vec<Vec<f32>>>,
    ) -> (Leader, Vec<std::thread::JoinHandle<Result<()>>>) {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        spawn_local_cluster(proto, shards, mean_update(), 42)
    }

    #[test]
    fn mean_estimation_round_over_loopback() {
        let d = 32;
        let shards: Vec<Vec<Vec<f32>>> =
            (0..5).map(|i| vec![vec![i as f32 * 0.1; d]]).collect();
        let client_means: Vec<Vec<f32>> =
            shards.iter().map(|s| s[0].clone()).collect();
        let truth = stats::true_mean(&client_means);
        let (mut leader, handles) = cluster("klevel:k=64", d, shards);
        let out = leader.round(0, d as u32, &[]).unwrap();
        assert_eq!(out.means.len(), 1);
        assert_eq!(out.n_frames, 5);
        assert!(out.uplink_bits > 0);
        let err = stats::sq_error(&out.means[0], &truth);
        assert!(err < 1e-3, "err={err}");
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn multiple_rounds_and_metrics() {
        let d = 16;
        let shards: Vec<Vec<Vec<f32>>> = (0..3).map(|_| vec![vec![1.0; d]]).collect();
        let (mut leader, handles) = cluster("binary", d, shards);
        for r in 0..4 {
            leader.round(r, d as u32, &[]).unwrap();
        }
        assert_eq!(leader.metrics().rounds.len(), 4);
        let m = &leader.metrics().rounds[3];
        assert_eq!(m.round, 3);
        assert!(m.cum_up_bytes >= m.uplink_bits / 8);
        assert!(m.decode_wall > Duration::ZERO, "decode wall not measured");
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn decode_pool_width_does_not_change_round_bits() {
        // Same cluster, same seeds, different decode-thread counts: the
        // estimates must agree bit for bit (the merge is exact, so
        // scheduling cannot matter).
        let d = 64;
        let mk_shards = || -> Vec<Vec<Vec<f32>>> {
            (0..9).map(|i| vec![vec![0.3 + i as f32 * 0.7; d]]).collect()
        };
        let mut reference: Option<Vec<Vec<u32>>> = None;
        for threads in [1usize, 2, 8] {
            let (mut leader, handles) = cluster("rotated:k=16", d, mk_shards());
            leader.set_decode_threads(threads);
            let mut rounds = Vec::new();
            for r in 0..3 {
                let out = leader.round(r, d as u32, &[]).unwrap();
                rounds.push(out.means[0].iter().map(|v| v.to_bits()).collect::<Vec<_>>());
            }
            match &reference {
                None => reference = Some(rounds.concat()),
                Some(want) => {
                    assert_eq!(&rounds.concat(), want, "threads={threads} diverged");
                }
            }
            leader.shutdown().unwrap();
            for h in handles {
                h.join().unwrap().unwrap();
            }
        }
    }

    #[test]
    fn streaming_matches_reference_on_manual_uploads() {
        // Hand-built multi-slot uploads with ragged slot counts and mixed
        // weights, fed to both aggregation paths in scrambled order.
        let d = 16;
        let proto = ProtocolConfig::parse("float32", d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 5);
        let state = proto.prepare(&ctx);
        let mut enc = Encoder::new(proto.as_ref(), &state);
        let mut uploads: Vec<(u64, Vec<WeightedFrame>)> = Vec::new();
        for client in 0..5u64 {
            let n_slots = 1 + (client as usize) % 3; // ragged: 1..=3 slots
            let mut frames = Vec::new();
            for slot in 0..n_slots {
                let x = vec![client as f32 + slot as f32 * 0.1; d];
                let frame = enc.encode(client * 10 + slot as u64, &x).unwrap();
                let weight = if client == 2 { 3.0 } else { 1.0 }; // mixed
                frames.push(WeightedFrame { frame, weight });
            }
            // client 4 additionally uploads a silent frame
            if client == 4 {
                frames.push(WeightedFrame {
                    frame: crate::protocol::Frame::new(Vec::new(), 0),
                    weight: 0.0,
                });
            }
            uploads.push((client, frames));
        }
        let want = aggregate_uploads_reference(proto.as_ref(), &state, uploads.clone()).unwrap();
        uploads.reverse(); // scrambled arrival
        for threads in [1usize, 2, 8] {
            let got =
                aggregate_uploads_streaming(proto.as_ref(), &state, &uploads, threads).unwrap();
            assert_eq!(got.uplink_bits, want.uplink_bits);
            assert_eq!(got.n_frames, want.n_frames);
            assert_eq!(got.weights, want.weights);
            assert_eq!(got.means.len(), want.means.len());
            for (a, b) in got.means.iter().zip(&want.means) {
                assert_eq!(
                    a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "threads={threads}"
                );
            }
        }
    }

    #[test]
    fn fold_spans_handles_silent_and_ragged_slots() {
        // Direct unit coverage of the merge with silent partials and
        // ragged slot counts — the shapes sampling protocols produce.
        let d = 8;
        let proto = ProtocolConfig::parse("float32", d).unwrap().build().unwrap();
        let ctx = RoundCtx::new(0, 3);
        let state = proto.prepare(&ctx);
        let dim = proto.new_accumulator().sum.len();
        let decoded = vec![
            DecodedUpload {
                origin: ChildKey::Client(0),
                slots: vec![
                    Some(SlotPartial::from_decoded(&vec![2.0; dim], 1.0, 1).unwrap()),
                    None, // silent frame
                ],
                uplink_bits: 32,
                n_frames: 1,
            },
            DecodedUpload {
                origin: ChildKey::Client(1),
                slots: vec![None], // ragged: one slot only, silent
                uplink_bits: 0,
                n_frames: 0,
            },
            DecodedUpload {
                origin: ChildKey::Client(2),
                slots: vec![
                    Some(SlotPartial::from_decoded(&vec![4.0; dim], 1.0, 1).unwrap()),
                    Some(SlotPartial::from_decoded(&vec![1.0; dim], 1.0, 1).unwrap()),
                ],
                uplink_bits: 64,
                n_frames: 2,
            },
        ];
        let slots = fold_spans(proto.as_ref(), &decoded).unwrap();
        assert_eq!(slots.len(), 2);
        assert_eq!(slots[0].holders, 3);
        assert_eq!(slots[0].frames, 2);
        assert_eq!(slots[1].holders, 2);
        assert_eq!(slots[1].frames, 1);
        let (mean0, w0) = slots[0].finish(proto.as_ref(), &state);
        // Plain mean over holders: (2 + 4 + silent 0) / 3.
        assert_eq!(w0, 2.0);
        for &v in &mean0 {
            assert!((v - 2.0).abs() < 1e-6, "{v}");
        }
    }

    #[test]
    fn eager_span_accum_matches_batch_fold_for_any_thread_split() {
        // The eager per-thread fold contract: splitting children across
        // any number of per-thread accumulators and absorbing them in any
        // order is bit-identical to the batch fold_spans over the whole
        // list — including ragged slot counts and silent slots.
        let d = 12;
        let proto = ProtocolConfig::parse("float32", d).unwrap().build().unwrap();
        let dim = proto.internal_dim();
        let mk = |v: f32, w: f32, slots: usize, silent_last: bool| DecodedUpload {
            origin: ChildKey::Client(0),
            slots: (0..slots)
                .map(|s| {
                    if silent_last && s + 1 == slots {
                        None
                    } else {
                        Some(SlotPartial::from_decoded(&vec![v + s as f32; dim], w, 1).unwrap())
                    }
                })
                .collect(),
            uplink_bits: 32 * slots as u64,
            n_frames: slots - silent_last as usize,
        };
        let decoded = vec![
            mk(1.0, 1.0, 2, false),
            mk(-3.0, 2.5, 1, false),
            mk(0.25, 1.0, 3, true),
            mk(7.0, 0.5, 2, false),
            mk(2.0, 1.0, 1, true),
        ];
        let want = fold_spans(proto.as_ref(), &decoded).unwrap();
        for split in [1usize, 2, 3, 5] {
            let mut per_thread: Vec<SpanAccum> =
                (0..split).map(|_| SpanAccum::new(dim)).collect();
            for (i, u) in decoded.iter().enumerate() {
                per_thread[i % split].fold(u).unwrap();
            }
            let mut main = SpanAccum::new(dim);
            // Absorb in reverse to prove order-independence too.
            for acc in per_thread.into_iter().rev() {
                main.absorb(acc).unwrap();
            }
            assert_eq!(main.uplink_bits(), decoded.iter().map(|d| d.uplink_bits).sum::<u64>());
            assert_eq!(
                main.n_frames(),
                decoded.iter().map(|d| d.n_frames as u64).sum::<u64>()
            );
            let got = main.into_slots();
            assert_eq!(got, want, "split={split} diverged from the batch fold");
        }
    }

    #[test]
    fn fold_frames_matches_decode_upload_fold() {
        // The decode pool's zero-allocation streaming fold must be
        // bit-identical to the batch decode-then-fold path, including
        // silent frames, mixed weights, and sampling protocols.
        let d = 24;
        for spec in ["float32", "rotated:k=16", "klevel:k=32,p=0.5"] {
            let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
            let ctx = RoundCtx::new(1, 9);
            let state = proto.prepare(&ctx);
            let dim = proto.internal_dim();
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let mut frames = Vec::new();
            for slot in 0..3u64 {
                let x: Vec<f32> = (0..d).map(|j| j as f32 * 0.3 - slot as f32).collect();
                let wf = match enc.encode(slot * 7 + 1, &x) {
                    Some(frame) => WeightedFrame { frame, weight: 0.5 + slot as f32 },
                    None => WeightedFrame {
                        frame: crate::protocol::Frame::new(Vec::new(), 0),
                        weight: 0.0,
                    },
                };
                frames.push(wf);
            }
            // An explicitly silent trailing frame.
            frames.push(WeightedFrame {
                frame: crate::protocol::Frame::new(Vec::new(), 0),
                weight: 0.0,
            });
            let mut batch = SpanAccum::new(dim);
            batch.fold(&decode_upload(proto.as_ref(), &state, 1, &frames).unwrap()).unwrap();
            let mut streaming = SpanAccum::new(dim);
            let mut scratch = proto.new_accumulator();
            streaming.fold_frames(proto.as_ref(), &state, &frames, &mut scratch).unwrap();
            assert_eq!(streaming.uplink_bits(), batch.uplink_bits(), "spec={spec}");
            assert_eq!(streaming.n_frames(), batch.n_frames(), "spec={spec}");
            assert_eq!(streaming.into_slots(), batch.into_slots(), "spec={spec}");
        }
    }

    #[test]
    fn failing_worker_errors_the_round_instead_of_deadlocking() {
        // A worker whose step() fails (here: stream-id packing overflow)
        // sends a barrier-wakeup before dying, so the leader's round
        // returns Err instead of blocking forever on the barrier.
        let d = 8;
        let proto = ProtocolConfig::parse("klevel:k=4", d).unwrap().build().unwrap();
        let (hub, mut endpoints) = crate::coordinator::transport::LoopbackHub::new(2);
        // The dead worker takes the LOWER endpoint index: shutdown must
        // still reach the healthy worker behind it (broadcast is
        // best-effort, not fail-fast).
        let ep_good = endpoints.pop().unwrap();
        let ep_bad = endpoints.pop().unwrap();
        let mk = |client_id| crate::coordinator::worker::Worker {
            client_id,
            shard: vec![vec![1.0; d]],
            protocol: proto.clone(),
            update: mean_update(),
            seed: 3,
        };
        let good = mk(0);
        let bad = mk(1 << 40); // client id overflows the stream-id field
        let h_good = std::thread::spawn(move || good.run_loopback(ep_good));
        let h_bad = std::thread::spawn(move || bad.run_loopback(ep_bad));
        let mut leader = Leader::new(proto, Box::new(hub), 3);
        assert!(leader.round(0, d as u32, &[]).is_err(), "round must error, not hang");
        // The dead worker's endpoint is gone, so shutdown may only reach
        // the surviving worker — best effort is all that is required.
        let _ = leader.shutdown();
        assert!(h_good.join().unwrap().is_ok());
        assert!(h_bad.join().unwrap().is_err());
    }

    #[test]
    fn weighted_slots_aggregate_correctly() {
        // Two workers, one slot, weights 1 and 3: mean = (1*a + 3*b)/4.
        let d = 8;
        let proto = ProtocolConfig::parse("float32", d).unwrap().build().unwrap();
        let update: super::super::worker::UpdateFn = Arc::new(move |_b, _dim, shard| {
            let w = shard[0][0]; // smuggle the weight via the shard
            vec![(vec![w; 8], w)]
        });
        let shards = vec![vec![vec![1.0f32; d]], vec![vec![3.0f32; d]]];
        let (mut leader, handles) =
            spawn_local_cluster(proto, shards, update, 7);
        let out = leader.round(0, d as u32, &[]).unwrap();
        let expect = (1.0 * 1.0 + 3.0 * 3.0) / 4.0;
        for &v in &out.means[0] {
            assert!((v - expect).abs() < 1e-6, "{v} vs {expect}");
        }
        assert_eq!(out.weights[0], 4.0);
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn sampling_protocol_keeps_barrier() {
        // With p=0.5 some workers stay silent; the round must still finish
        // and remain unbiased thanks to Lemma 8 scaling.
        let d = 16;
        let n = 40;
        let shards: Vec<Vec<Vec<f32>>> = (0..n).map(|_| vec![vec![2.0; d]]).collect();
        let (mut leader, handles) = cluster("klevel:k=32,p=0.5", d, shards);
        let mut est_sum = vec![0.0f64; d];
        let rounds = 60;
        for r in 0..rounds {
            let out = leader.round(r, d as u32, &[]).unwrap();
            assert!(out.n_frames < n); // some silenced (overwhelmingly likely)
            for (s, &v) in est_sum.iter_mut().zip(&out.means[0]) {
                *s += v as f64;
            }
        }
        // Per-round std of each coordinate is 2·√((1−p)/(np)) ≈ 0.32;
        // over 60 rounds the mean's std is ≈ 0.041 — allow ~6σ.
        for &s in &est_sum {
            let mean = s / rounds as f64;
            assert!((mean - 2.0).abs() < 0.25, "mean {mean} vs 2.0");
        }
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }
}
