//! The leader (server) side of the coordinator: drives rounds, enforces
//! the barrier, decodes uploads, and aggregates per-slot weighted means.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use super::metrics::{ExperimentMetrics, RoundMetrics};
use super::transport::{Message, TransportHub, WeightedFrame};
use crate::protocol::{Decoder, Protocol, RoundCtx};

/// Result of one coordinated round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// Aggregated mean per slot (slot = position in each worker's upload,
    /// e.g. cluster index for Lloyd's; one slot for plain mean estimation).
    pub means: Vec<Vec<f32>>,
    /// Total weight per slot.
    pub weights: Vec<f64>,
    /// Exact uplink payload bits this round (sum of frame bit lengths).
    pub uplink_bits: u64,
    /// Number of non-silent frames received.
    pub n_frames: usize,
}

/// The coordinator leader.
pub struct Leader {
    protocol: Arc<dyn Protocol>,
    hub: Box<dyn TransportHub>,
    seed: u64,
    metrics: ExperimentMetrics,
}

impl Leader {
    pub fn new(protocol: Arc<dyn Protocol>, hub: Box<dyn TransportHub>, seed: u64) -> Self {
        Leader { protocol, hub, seed, metrics: ExperimentMetrics::default() }
    }

    pub fn n_workers(&self) -> usize {
        self.hub.n_workers()
    }

    pub fn metrics(&self) -> &ExperimentMetrics {
        &self.metrics
    }

    /// Run one synchronous round: broadcast `state` (`n_slots × dim`
    /// flattened — what the workers need to compute their updates), wait
    /// for every worker's upload, decode and aggregate.
    pub fn round(&mut self, round: u64, dim: u32, state: &[f32]) -> Result<RoundOutcome> {
        let t0 = Instant::now();
        let n_workers = self.hub.n_workers();
        ensure!(n_workers > 0, "no workers connected");
        self.hub.broadcast(&Message::RoundStart {
            round,
            dim,
            payload: state.to_vec(),
        })?;

        // Barrier: exactly one upload per worker.
        let mut uploads: Vec<(u64, Vec<WeightedFrame>)> = Vec::with_capacity(n_workers);
        let mut seen = std::collections::HashSet::new();
        while uploads.len() < n_workers {
            match self.hub.recv()? {
                Message::Upload { client, round: r, frames } => {
                    ensure!(r == round, "worker {client} answered round {r}, expected {round}");
                    ensure!(seen.insert(client), "duplicate upload from worker {client}");
                    uploads.push((client, frames));
                }
                Message::RoundStart { .. } | Message::Shutdown => {
                    bail!("unexpected message at the leader")
                }
            }
        }

        // Deterministic aggregation: decode in client-id order regardless
        // of arrival order (f32 addition is not associative; without this
        // the same round could produce different bit patterns run-to-run).
        uploads.sort_by_key(|(client, _)| *client);

        // Slot count: max over workers (workers with empty shards send 0).
        let n_slots = uploads.iter().map(|(_, f)| f.len()).max().unwrap_or(0);
        let ctx = RoundCtx::new(round, self.seed);
        // One round session: shared state (the rotation for π_srk) is
        // prepared once and reused across every slot and frame.
        let proto = self.protocol.as_ref();
        let round_state = proto.prepare(&ctx);

        let mut means = Vec::with_capacity(n_slots);
        let mut weights = Vec::with_capacity(n_slots);
        let mut uplink_bits = 0u64;
        let mut n_frames = 0usize;

        for slot in 0..n_slots {
            // Frames decode in client-id order (uploads are sorted above):
            // f32 accumulation order is part of the determinism guarantee.
            let slot_frames: Vec<&WeightedFrame> = uploads
                .iter()
                .filter_map(|(_, f)| f.get(slot))
                .filter(|wf| wf.frame.bit_len > 0)
                .collect();
            uplink_bits += slot_frames.iter().map(|wf| wf.frame.bit_len).sum::<u64>();
            n_frames += slot_frames.len();
            let holders = uploads.iter().filter(|(_, f)| f.get(slot).is_some()).count();

            let mut dec = Decoder::new(proto, &round_state);
            let uniform = slot_frames.iter().all(|wf| wf.weight == 1.0);
            if uniform {
                // Plain-mean fast path: every present frame has weight 1.0.
                for wf in &slot_frames {
                    dec.push(&wf.frame)?;
                }
                weights.push(slot_frames.len() as f64);
                means.push(dec.finish(holders));
            } else {
                // Weighted average: the decoder folds weight-scaled frames
                // in the protocol's internal space, so the inverse rotation
                // runs once per slot instead of once per frame.
                for wf in &slot_frames {
                    dec.push_weighted(&wf.frame, wf.weight)?;
                }
                weights.push(dec.total_weight());
                means.push(dec.finish_weighted());
            }
        }

        let (down, up) = self.hub.bytes_moved();
        self.metrics.push(RoundMetrics {
            round,
            uplink_bits,
            n_frames,
            wall: t0.elapsed(),
            cum_down_bytes: down,
            cum_up_bytes: up,
        });
        Ok(RoundOutcome { means, weights, uplink_bits, n_frames })
    }

    /// Broadcast shutdown to all workers.
    pub fn shutdown(&mut self) -> Result<()> {
        self.hub.broadcast(&Message::Shutdown)
    }
}

/// Spawn `shards.len()` loopback worker threads plus a leader — the
/// single-process cluster used by examples, tests, and benches.
pub fn spawn_local_cluster(
    protocol: Arc<dyn Protocol>,
    shards: Vec<Vec<Vec<f32>>>,
    update: super::worker::UpdateFn,
    seed: u64,
) -> (Leader, Vec<std::thread::JoinHandle<Result<()>>>) {
    let n = shards.len();
    let (hub, endpoints) = super::transport::LoopbackHub::new(n);
    let mut handles = Vec::with_capacity(n);
    for (i, (shard, ep)) in shards.into_iter().zip(endpoints).enumerate() {
        let worker = super::worker::Worker {
            client_id: i as u64,
            shard,
            protocol: protocol.clone(),
            update: update.clone(),
            seed,
        };
        handles.push(
            std::thread::Builder::new()
                .name(format!("dme-worker-{i}"))
                .spawn(move || worker.run_loopback(ep))
                .expect("spawning worker thread"),
        );
    }
    (Leader::new(protocol, Box::new(hub), seed), handles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::mean_update;
    use crate::protocol::config::ProtocolConfig;
    use crate::stats;

    fn cluster(
        spec: &str,
        d: usize,
        shards: Vec<Vec<Vec<f32>>>,
    ) -> (Leader, Vec<std::thread::JoinHandle<Result<()>>>) {
        let proto = ProtocolConfig::parse(spec, d).unwrap().build().unwrap();
        spawn_local_cluster(proto, shards, mean_update(), 42)
    }

    #[test]
    fn mean_estimation_round_over_loopback() {
        let d = 32;
        let shards: Vec<Vec<Vec<f32>>> =
            (0..5).map(|i| vec![vec![i as f32 * 0.1; d]]).collect();
        let client_means: Vec<Vec<f32>> =
            shards.iter().map(|s| s[0].clone()).collect();
        let truth = stats::true_mean(&client_means);
        let (mut leader, handles) = cluster("klevel:k=64", d, shards);
        let out = leader.round(0, d as u32, &[]).unwrap();
        assert_eq!(out.means.len(), 1);
        assert_eq!(out.n_frames, 5);
        assert!(out.uplink_bits > 0);
        let err = stats::sq_error(&out.means[0], &truth);
        assert!(err < 1e-3, "err={err}");
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn multiple_rounds_and_metrics() {
        let d = 16;
        let shards: Vec<Vec<Vec<f32>>> = (0..3).map(|_| vec![vec![1.0; d]]).collect();
        let (mut leader, handles) = cluster("binary", d, shards);
        for r in 0..4 {
            leader.round(r, d as u32, &[]).unwrap();
        }
        assert_eq!(leader.metrics().rounds.len(), 4);
        let m = &leader.metrics().rounds[3];
        assert_eq!(m.round, 3);
        assert!(m.cum_up_bytes >= m.uplink_bits / 8);
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn weighted_slots_aggregate_correctly() {
        // Two workers, one slot, weights 1 and 3: mean = (1*a + 3*b)/4.
        let d = 8;
        let proto = ProtocolConfig::parse("float32", d).unwrap().build().unwrap();
        let update: super::super::worker::UpdateFn = Arc::new(move |_b, _dim, shard| {
            let w = shard[0][0]; // smuggle the weight via the shard
            vec![(vec![w; 8], w)]
        });
        let shards = vec![vec![vec![1.0f32; d]], vec![vec![3.0f32; d]]];
        let (mut leader, handles) =
            spawn_local_cluster(proto, shards, update, 7);
        let out = leader.round(0, d as u32, &[]).unwrap();
        let expect = (1.0 * 1.0 + 3.0 * 3.0) / 4.0;
        for &v in &out.means[0] {
            assert!((v - expect).abs() < 1e-6, "{v} vs {expect}");
        }
        assert_eq!(out.weights[0], 4.0);
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn sampling_protocol_keeps_barrier() {
        // With p=0.5 some workers stay silent; the round must still finish
        // and remain unbiased thanks to Lemma 8 scaling.
        let d = 16;
        let n = 40;
        let shards: Vec<Vec<Vec<f32>>> = (0..n).map(|_| vec![vec![2.0; d]]).collect();
        let (mut leader, handles) = cluster("klevel:k=32,p=0.5", d, shards);
        let mut est_sum = vec![0.0f64; d];
        let rounds = 60;
        for r in 0..rounds {
            let out = leader.round(r, d as u32, &[]).unwrap();
            assert!(out.n_frames < n); // some silenced (overwhelmingly likely)
            for (s, &v) in est_sum.iter_mut().zip(&out.means[0]) {
                *s += v as f64;
            }
        }
        // Per-round std of each coordinate is 2·√((1−p)/(np)) ≈ 0.32;
        // over 60 rounds the mean's std is ≈ 0.041 — allow ~6σ.
        for &s in &est_sum {
            let mean = s / rounds as f64;
            assert!((mean - 2.0).abs() < 0.25, "mean {mean} vs 2.0");
        }
        leader.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }
}
