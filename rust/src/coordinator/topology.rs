//! Aggregation-tree topology: how workers, aggregation-tier nodes, and
//! the leader are arranged.
//!
//! A [`Topology`] describes a tree over the client-id range
//! `[0, n_clients)`: level 0 of [`Topology::levels`] is the aggregator
//! tier directly above the workers, higher levels sit above it, and the
//! leader takes whatever the top level exposes ([`Topology::root_children`]).
//! Every aggregator owns a contiguous client span, spans at each level
//! partition `[0, n_clients)`, and a child's span is always contained in
//! its parent's — the invariants [`Topology::validate`] checks and the
//! coordinator relies on for its span-disjointness barrier checks.
//!
//! Because the aggregation state itself is exactly mergeable
//! (`SlotPartial`), the *shape* of the tree never changes the root
//! estimate — topology is purely a deployment/throughput decision: a
//! deeper tree trades hops for a smaller fan-in (and so a smaller ingest
//! load) at every node, shrinking root ingest from O(n · frames) to
//! O(root-fan-in · slots).

use anyhow::{ensure, Result};

/// One child of an aggregator (or of the leader): either a worker
/// (leaf), or an aggregator at `levels[level][index]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Child {
    Worker(u64),
    Agg { level: usize, index: usize },
}

/// One aggregation-tier node: its wire id, the contiguous client span it
/// covers, and its direct children.
#[derive(Clone, Debug)]
pub struct AggSpec {
    /// Unique id across the whole tree (what `PartialUpload` carries).
    pub id: u64,
    /// Covered clients `[span.0, span.1)`.
    pub span: (u64, u64),
    pub children: Vec<Child>,
}

/// A tree arrangement of workers → aggregators → leader.
#[derive(Clone, Debug)]
pub struct Topology {
    n_clients: u64,
    /// `levels[0]` is the tier directly above the workers; the last
    /// entry is the tier directly below the leader. Empty = flat.
    levels: Vec<Vec<AggSpec>>,
    /// How many contiguous dimension shards the aggregation state is
    /// split into (1 = unsharded). Orthogonal to the client-span tree:
    /// with `s` shards, each barrier child is logically replicated `s`
    /// times, one replica folding only its slice of the coordinates, and
    /// the root concatenates the slices (see `Topology::shard_ranges`).
    dim_shards: u32,
}

impl Topology {
    /// The flat topology: every worker reports straight to the leader.
    pub fn flat(n_clients: u64) -> Self {
        Topology { n_clients, levels: Vec::new(), dim_shards: 1 }
    }

    /// Split the aggregation state into `shards` contiguous dimension
    /// slices (1 = unsharded, the default). The estimate is bit-identical
    /// for every shard count — coordinate sums are independent — so this
    /// is purely a capacity decision: it bounds per-aggregator slot state
    /// to `internal_dim / shards` coordinates.
    pub fn with_dim_shards(mut self, shards: u32) -> Result<Self> {
        ensure!(shards >= 1, "dim_shards must be at least 1");
        ensure!(shards <= 1 << 16, "dim_shards {shards} is absurdly large");
        self.dim_shards = shards;
        Ok(self)
    }

    /// How many dimension shards the aggregation state is split into.
    pub fn dim_shards(&self) -> u32 {
        self.dim_shards
    }

    /// The contiguous coordinate ranges `[lo, hi)` the shards cover at a
    /// given protocol-internal dimension: balanced slices (sizes differ
    /// by at most one, larger slices first), partitioning
    /// `[0, internal_dim)` in order. Shards beyond `internal_dim` are
    /// empty ranges — legal, they just hold no coordinates.
    pub fn shard_ranges(&self, internal_dim: usize) -> Vec<(u32, u32)> {
        split_ranges(internal_dim, self.dim_shards)
    }

    /// A uniform tree: `depth` barrier tiers (1 = flat, 2 = one
    /// aggregator tier, …), each aggregator taking at most `fanout`
    /// consecutive children from the tier below.
    pub fn uniform(n_clients: u64, fanout: usize, depth: usize) -> Result<Self> {
        ensure!(n_clients >= 1, "topology needs at least one client");
        ensure!(fanout >= 1, "fanout must be at least 1");
        ensure!((1..=16).contains(&depth), "depth must be in 1..=16");
        let mut levels: Vec<Vec<AggSpec>> = Vec::new();
        // The tier below the one being built: (span, child handle).
        let mut below: Vec<((u64, u64), Child)> =
            (0..n_clients).map(|c| ((c, c + 1), Child::Worker(c))).collect();
        let mut next_id = 0u64;
        for level in 0..depth.saturating_sub(1) {
            let mut tier = Vec::with_capacity(below.len().div_ceil(fanout));
            for chunk in below.chunks(fanout) {
                let span = (chunk[0].0 .0, chunk[chunk.len() - 1].0 .1);
                tier.push(AggSpec {
                    id: next_id,
                    span,
                    children: chunk.iter().map(|&(_, c)| c).collect(),
                });
                next_id += 1;
            }
            below = tier
                .iter()
                .enumerate()
                .map(|(index, spec)| (spec.span, Child::Agg { level, index }))
                .collect();
            levels.push(tier);
        }
        let topo = Topology { n_clients, levels, dim_shards: 1 };
        topo.validate()?;
        Ok(topo)
    }

    pub fn n_clients(&self) -> u64 {
        self.n_clients
    }

    /// Number of barrier tiers, counting the leader's (flat = 1).
    pub fn depth(&self) -> usize {
        self.levels.len() + 1
    }

    /// Aggregator tiers, bottom-up; empty for the flat topology.
    pub fn levels(&self) -> &[Vec<AggSpec>] {
        &self.levels
    }

    pub fn spec(&self, level: usize, index: usize) -> &AggSpec {
        &self.levels[level][index]
    }

    /// Total number of aggregation-tier nodes.
    pub fn n_aggregators(&self) -> usize {
        self.levels.iter().map(|t| t.len()).sum()
    }

    /// The leader's direct children.
    pub fn root_children(&self) -> Vec<Child> {
        match self.levels.last() {
            None => (0..self.n_clients).map(Child::Worker).collect(),
            Some(top) => (0..top.len())
                .map(|index| Child::Agg { level: self.levels.len() - 1, index })
                .collect(),
        }
    }

    /// How many children the leader ingests per round.
    pub fn root_fan_in(&self) -> usize {
        match self.levels.last() {
            None => self.n_clients as usize,
            Some(top) => top.len(),
        }
    }

    /// The span a child handle covers.
    pub fn child_span(&self, child: &Child) -> (u64, u64) {
        match child {
            Child::Worker(c) => (*c, c + 1),
            Child::Agg { level, index } => self.levels[*level][*index].span,
        }
    }

    /// Check the structural invariants: every tier's spans partition
    /// `[0, n_clients)` in order, children are contiguous and contained
    /// in their parent's span, and ids are unique.
    pub fn validate(&self) -> Result<()> {
        let mut ids = std::collections::HashSet::new();
        for (level, tier) in self.levels.iter().enumerate() {
            let mut cursor = 0u64;
            for spec in tier {
                ensure!(ids.insert(spec.id), "duplicate aggregator id {}", spec.id);
                ensure!(spec.span.0 == cursor, "tier {level} spans leave a gap at {cursor}");
                ensure!(spec.span.1 > spec.span.0, "aggregator {} has an empty span", spec.id);
                ensure!(!spec.children.is_empty(), "aggregator {} has no children", spec.id);
                let mut child_cursor = spec.span.0;
                for child in &spec.children {
                    let (lo, hi) = self.child_span(child);
                    ensure!(
                        lo == child_cursor && hi <= spec.span.1,
                        "aggregator {}: child span [{lo}, {hi}) breaks its span {:?}",
                        spec.id,
                        spec.span
                    );
                    if let Child::Agg { level: cl, .. } = child {
                        ensure!(level > 0 && *cl == level - 1, "child tier must be one below");
                    }
                    child_cursor = hi;
                }
                ensure!(child_cursor == spec.span.1, "aggregator {} span not covered", spec.id);
                cursor = spec.span.1;
            }
            ensure!(cursor == self.n_clients, "tier {level} does not cover all clients");
        }
        Ok(())
    }

    /// One-line human description, e.g.
    /// `"4096 workers → 64 aggs (fan-in 64) → 1 agg (fan-in 64) → leader (fan-in 1)"`.
    pub fn describe(&self) -> String {
        let mut s = format!("{} workers", self.n_clients);
        for tier in &self.levels {
            let max_fan = tier.iter().map(|a| a.children.len()).max().unwrap_or(0);
            s.push_str(&format!(" → {} aggs (fan-in ≤ {})", tier.len(), max_fan));
        }
        s.push_str(&format!(" → leader (fan-in {})", self.root_fan_in()));
        s
    }
}

/// Balanced contiguous partition of `[0, dim)` into `shards` ranges:
/// the first `dim % shards` ranges get one extra coordinate.
pub fn split_ranges(dim: usize, shards: u32) -> Vec<(u32, u32)> {
    let shards = shards.max(1) as usize;
    let base = dim / shards;
    let extra = dim % shards;
    let mut out = Vec::with_capacity(shards);
    let mut lo = 0usize;
    for s in 0..shards {
        let hi = lo + base + usize::from(s < extra);
        out.push((lo as u32, hi as u32));
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_topology_is_depth_one() {
        let t = Topology::flat(5);
        assert_eq!(t.depth(), 1);
        assert_eq!(t.n_aggregators(), 0);
        assert_eq!(t.root_fan_in(), 5);
        assert_eq!(t.root_children().len(), 5);
        assert!(t.validate().is_ok());
        assert_eq!(Topology::uniform(5, 8, 1).unwrap().n_aggregators(), 0);
    }

    #[test]
    fn uniform_depth2_partitions_clients() {
        let t = Topology::uniform(36, 32, 2).unwrap();
        assert_eq!(t.depth(), 2);
        assert_eq!(t.levels().len(), 1);
        assert_eq!(t.levels()[0].len(), 2);
        assert_eq!(t.levels()[0][0].span, (0, 32));
        assert_eq!(t.levels()[0][1].span, (32, 36));
        assert_eq!(t.root_fan_in(), 2);
        assert!(t.describe().contains("36 workers"));
    }

    #[test]
    fn uniform_depth3_nests_spans() {
        let t = Topology::uniform(100, 7, 3).unwrap();
        assert_eq!(t.depth(), 3);
        assert_eq!(t.levels()[0].len(), 15); // ceil(100/7)
        assert_eq!(t.levels()[1].len(), 3); // ceil(15/7)
        assert_eq!(t.root_fan_in(), 3);
        assert_eq!(t.n_aggregators(), 18);
        // ids unique and spans nested — validate() checks it all.
        assert!(t.validate().is_ok());
    }

    #[test]
    fn degenerate_shapes() {
        // fanout 1: a chain tier with one aggregator per worker.
        let t = Topology::uniform(4, 1, 2).unwrap();
        assert_eq!(t.levels()[0].len(), 4);
        assert_eq!(t.root_fan_in(), 4);
        // fanout ≥ n: a single aggregator holding everyone.
        let t = Topology::uniform(4, 64, 2).unwrap();
        assert_eq!(t.levels()[0].len(), 1);
        assert_eq!(t.root_fan_in(), 1);
        // deeper than useful: chains of singleton aggregators are legal.
        let t = Topology::uniform(3, 8, 4).unwrap();
        assert_eq!(t.depth(), 4);
        assert_eq!(t.levels()[1].len(), 1);
        assert_eq!(t.levels()[2].len(), 1);
    }

    #[test]
    fn invalid_parameters_rejected() {
        assert!(Topology::uniform(0, 4, 2).is_err());
        assert!(Topology::uniform(4, 0, 2).is_err());
        assert!(Topology::uniform(4, 4, 0).is_err());
        assert!(Topology::uniform(4, 4, 17).is_err());
        assert!(Topology::flat(4).with_dim_shards(0).is_err());
    }

    #[test]
    fn shard_ranges_partition_the_dimension() {
        // Default is the unsharded identity range.
        assert_eq!(Topology::flat(4).shard_ranges(10), vec![(0, 10)]);
        for (dim, shards) in
            [(10usize, 1u32), (10, 3), (10, 10), (7, 4), (1, 5), (0, 3), (1 << 20, 7)]
        {
            let ranges = Topology::flat(4).with_dim_shards(shards).unwrap().shard_ranges(dim);
            assert_eq!(ranges.len(), shards as usize);
            let mut cursor = 0u32;
            for &(lo, hi) in &ranges {
                assert_eq!(lo, cursor, "gap/overlap at dim={dim} shards={shards}");
                assert!(hi >= lo);
                cursor = hi;
            }
            assert_eq!(cursor as usize, dim, "ranges must cover [0, dim)");
            // Balanced: sizes differ by at most one, larger first.
            let sizes: Vec<u32> = ranges.iter().map(|&(lo, hi)| hi - lo).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1);
            assert!(sizes.windows(2).all(|w| w[0] >= w[1]));
        }
    }
}
