//! Session multiplexing: several concurrent tenant sessions over ONE
//! transport hub and one aggregation tree.
//!
//! A [`SessionMux`] wraps any [`TransportHub`] and hands out per-session
//! [`SessionHubView`]s, each of which *is* a `TransportHub` — so a
//! [`Leader`](super::leader::Leader) built on a view runs unmodified,
//! believing it owns the wire. The mux demultiplexes upstream envelopes
//! by their session id: a view's `recv_env` pops its own queue first,
//! then pulls from the shared hub, parking envelopes addressed to other
//! registered sessions on their queues. An envelope for a session nobody
//! registered is a typed [`WireError::UnknownSession`] — the envelope
//! contract: never silently dropped, never misattributed.
//!
//! Byte accounting is per tenant: every framed envelope that crosses the
//! mux is charged to the session in its header, so `dme serve --tenants`
//! can print an honest per-tenant bytes column even though the tenants
//! share every socket.
//!
//! Concurrency: views serialize on one mutex, and the lock is held
//! across the blocking `recv_env` on the underlying hub. That is safe —
//! a blocked holder routes other tenants' envelopes to their queues
//! before returning, so their views drain without touching the hub — but
//! it means tenant *drivers* make progress one wire-read at a time. The
//! intended pattern is the one `dme serve --tenants` uses: a single
//! driver thread interleaving tenant rounds, which needs no concurrency
//! at all.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::transport::{Envelope, Message, TransportHub, WireError};

struct MuxInner {
    hub: Box<dyn TransportHub>,
    /// Parked upstream envelopes, per registered session.
    queues: HashMap<u16, VecDeque<Envelope>>,
    /// Framed bytes broadcast down, per session (across all workers).
    down_bytes: HashMap<u16, u64>,
    /// Framed bytes received up, per session.
    up_bytes: HashMap<u16, u64>,
}

/// Multiplexes one [`TransportHub`] across many tenant sessions.
pub struct SessionMux {
    inner: Arc<Mutex<MuxInner>>,
}

impl SessionMux {
    /// Take ownership of `hub`; tenants attach via [`Self::view`].
    pub fn new(hub: Box<dyn TransportHub>) -> Self {
        SessionMux {
            inner: Arc::new(Mutex::new(MuxInner {
                hub,
                queues: HashMap::new(),
                down_bytes: HashMap::new(),
                up_bytes: HashMap::new(),
            })),
        }
    }

    /// Register `session` and return its hub view. Registration is what
    /// makes inbound envelopes for the session parkable: envelopes for
    /// unregistered sessions are typed errors, so register every tenant
    /// *before* the first round starts.
    pub fn view(&self, session: u16) -> SessionHubView {
        let mut g = self.inner.lock().unwrap();
        g.queues.entry(session).or_default();
        g.down_bytes.entry(session).or_default();
        g.up_bytes.entry(session).or_default();
        SessionHubView { session, inner: Arc::clone(&self.inner) }
    }

    /// Framed `(down, up)` bytes attributed to `session` so far.
    pub fn session_bytes(&self, session: u16) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (
            g.down_bytes.get(&session).copied().unwrap_or(0),
            g.up_bytes.get(&session).copied().unwrap_or(0),
        )
    }

    /// Registered session ids, ascending.
    pub fn sessions(&self) -> Vec<u16> {
        let g = self.inner.lock().unwrap();
        let mut out: Vec<u16> = g.queues.keys().copied().collect();
        out.sort_unstable();
        out
    }

    /// Total `(down, up)` bytes the underlying hub has moved — including
    /// traffic charged to no registered tenant (e.g. pre-mux rounds).
    pub fn bytes_moved(&self) -> (u64, u64) {
        self.inner.lock().unwrap().hub.bytes_moved()
    }
}

/// A per-session facade over the shared hub. Implements [`TransportHub`]
/// so leaders and aggregators drive it unchanged; `bytes_moved` reports
/// only this session's share.
pub struct SessionHubView {
    session: u16,
    inner: Arc<Mutex<MuxInner>>,
}

impl SessionHubView {
    /// The session this view speaks for.
    pub fn session(&self) -> u16 {
        self.session
    }

    /// Pop a parked envelope, else pull one from the hub via `pull`,
    /// parking strangers. `Ok(None)` only when `pull` returns it.
    fn next_from(
        &self,
        g: &mut MuxInner,
        pull: impl Fn(&mut dyn TransportHub) -> Result<Option<Envelope>>,
    ) -> Result<Option<Envelope>> {
        loop {
            if let Some(env) = g.queues.get_mut(&self.session).and_then(|q| q.pop_front()) {
                return Ok(Some(env));
            }
            let env = match pull(g.hub.as_mut())? {
                Some(env) => env,
                None => return Ok(None),
            };
            *g.up_bytes.entry(env.session).or_insert(0) += env.framed_len();
            if env.session == self.session {
                return Ok(Some(env));
            }
            match g.queues.get_mut(&env.session) {
                Some(q) => q.push_back(env),
                // A session nobody registered: surface the typed error
                // instead of guessing an owner or dropping the bytes.
                None => return Err(WireError::UnknownSession(env.session).into()),
            }
        }
    }
}

impl TransportHub for SessionHubView {
    fn n_workers(&self) -> usize {
        self.inner.lock().unwrap().hub.n_workers()
    }

    fn broadcast_session(&mut self, session: u16, msg: &Message) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        let fanout = g.hub.n_workers() as u64;
        g.hub.broadcast_session(session, msg)?;
        *g.down_bytes.entry(session).or_insert(0) += msg.framed_len() * fanout;
        Ok(())
    }

    fn recv_env(&mut self) -> Result<Envelope> {
        let mut g = self.inner.lock().unwrap();
        match self.next_from(&mut g, |hub| hub.recv_env().map(Some))? {
            Some(env) => Ok(env),
            None => unreachable!("blocking pull never yields None"),
        }
    }

    fn recv_env_timeout(&mut self, timeout: Duration) -> Result<Option<Envelope>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        self.next_from(&mut g, |hub| {
            let left = deadline.saturating_duration_since(Instant::now());
            hub.recv_env_timeout(left)
        })
    }

    fn bytes_moved(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (
            g.down_bytes.get(&self.session).copied().unwrap_or(0),
            g.up_bytes.get(&self.session).copied().unwrap_or(0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::{LoopbackHub, ROOT_SESSION};

    fn upload(client: u64) -> Message {
        Message::Upload { client, round: 0, frames: vec![] }
    }

    #[test]
    fn views_demux_by_session() {
        let (hub, eps) = LoopbackHub::new(2);
        let mux = SessionMux::new(Box::new(hub));
        let mut a = mux.view(1);
        let mut b = mux.view(2);

        // Interleave arrivals: b's envelope lands first, then a's.
        eps[0].send_session(2, upload(20)).unwrap();
        eps[1].send_session(1, upload(10)).unwrap();

        // a pulls: parks the session-2 envelope, returns its own.
        let env = a.recv_env().unwrap();
        assert_eq!(env.session, 1);
        assert!(matches!(env.msg, Message::Upload { client: 10, .. }));
        // b drains its parked envelope without touching the hub.
        let env = b.recv_env().unwrap();
        assert_eq!(env.session, 2);
        assert!(matches!(env.msg, Message::Upload { client: 20, .. }));
    }

    #[test]
    fn broadcast_goes_out_on_the_view_session() {
        let (hub, eps) = LoopbackHub::new(2);
        let mux = SessionMux::new(Box::new(hub));
        let mut a = mux.view(7);
        a.broadcast_session(7, &Message::Shutdown).unwrap();
        for ep in &eps {
            let env = ep.recv_envelope().unwrap();
            assert_eq!(env.session, 7);
            assert!(matches!(env.msg, Message::Shutdown));
        }
    }

    #[test]
    fn unregistered_session_is_a_typed_error() {
        let (hub, eps) = LoopbackHub::new(1);
        let mux = SessionMux::new(Box::new(hub));
        let mut a = mux.view(1);
        eps[0].send_session(9, upload(0)).unwrap();
        let err = a.recv_env().unwrap_err();
        match err.downcast_ref::<WireError>() {
            Some(WireError::UnknownSession(9)) => {}
            other => panic!("expected UnknownSession(9), got {other:?}"),
        }
    }

    #[test]
    fn per_session_byte_accounting_splits_the_wire() {
        let (hub, eps) = LoopbackHub::new(1);
        let mux = SessionMux::new(Box::new(hub));
        let mut a = mux.view(1);
        let mut b = mux.view(2);

        let down =
            Message::RoundStart { round: 0, shared_seed: 3, dim: 2, payload: vec![].into() };
        a.broadcast_session(1, &down).unwrap();
        a.broadcast_session(1, &down).unwrap();
        b.broadcast_session(2, &down).unwrap();
        // Drain the worker side so the channel doesn't pile up.
        for _ in 0..3 {
            eps[0].recv_envelope().unwrap();
        }

        eps[0].send_session(1, upload(0)).unwrap();
        eps[0].send_session(2, upload(0)).unwrap();
        a.recv_env().unwrap();
        b.recv_env().unwrap();

        let per_msg = down.framed_len();
        let per_up = upload(0).framed_len();
        assert_eq!(mux.session_bytes(1), (2 * per_msg, per_up));
        assert_eq!(mux.session_bytes(2), (per_msg, per_up));
        assert_eq!(a.bytes_moved(), (2 * per_msg, per_up));
        assert_eq!(b.bytes_moved(), (per_msg, per_up));
        // The hub's own tally covers both tenants.
        let (hub_down, hub_up) = mux.bytes_moved();
        assert_eq!(hub_down, 3 * per_msg);
        assert_eq!(hub_up, 2 * per_up);
    }

    #[test]
    fn timeout_elapses_without_eating_other_sessions() {
        let (hub, eps) = LoopbackHub::new(1);
        let mux = SessionMux::new(Box::new(hub));
        let mut a = mux.view(1);
        let mut b = mux.view(2);
        eps[0].send_session(2, upload(5)).unwrap();
        // a times out but must have parked b's envelope, not dropped it.
        assert!(a.recv_env_timeout(Duration::from_millis(20)).unwrap().is_none());
        let env = b.recv_env_timeout(Duration::from_millis(20)).unwrap().unwrap();
        assert_eq!(env.session, 2);
    }

    #[test]
    fn root_session_muxes_like_any_other() {
        // ROOT_SESSION is not special to the mux: a view on it coexists
        // with tenant views.
        let (hub, eps) = LoopbackHub::new(1);
        let mux = SessionMux::new(Box::new(hub));
        let mut root = mux.view(ROOT_SESSION);
        let mut t = mux.view(3);
        eps[0].send(upload(1)).unwrap(); // plain send = root session
        eps[0].send_session(3, upload(2)).unwrap();
        assert_eq!(root.recv_env().unwrap().session, ROOT_SESSION);
        assert_eq!(t.recv_env().unwrap().session, 3);
        assert_eq!(mux.sessions(), vec![ROOT_SESSION, 3]);
    }
}
