//! # dme — Distributed Mean Estimation with Limited Communication
//!
//! A production-grade reproduction of Suresh, Yu, Kumar, McMahan,
//! *Distributed Mean Estimation with Limited Communication* (ICML 2017),
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the communication protocols with bit-exact
//!   encoders/decoders, a leader/worker coordinator, the application
//!   drivers (distributed Lloyd's, distributed power iteration), and the
//!   bench harness that regenerates every figure in the paper.
//! * **L2/L1 (python/, build-time only)** — JAX graphs + Pallas kernels
//!   for the numeric hot-spots (Hadamard rotation, stochastic k-level
//!   quantization), AOT-lowered to HLO text in `artifacts/` and executed
//!   from Rust via PJRT ([`runtime`]). Python never runs on the request
//!   path.
//!
//! ## Protocols (paper section → module)
//!
//! | π | paper | module |
//! |---|-------|--------|
//! | `π_sb` stochastic binary | §2.1 | [`protocol::binary`] |
//! | `π_sk` stochastic k-level | §2.2 | [`protocol::klevel`] |
//! | `π_srk` stochastic rotated | §3 | [`protocol::rotated`] |
//! | `π_svk` variable-length coded | §4 | [`protocol::varlen`] |
//! | `π_p` client sampling | §5 | [`protocol::sampling`] |
//!
//! ## Quickstart
//!
//! ```no_run
//! use dme::protocol::{Protocol, RoundCtx, config::ProtocolConfig};
//!
//! let d = 256;
//! let cfg = ProtocolConfig::rotated(d, 16);
//! let proto = cfg.build().unwrap();
//! let ctx = RoundCtx::new(/*round=*/ 0, /*seed=*/ 42);
//!
//! // clients encode...
//! let xs: Vec<Vec<f32>> = (0..10).map(|_| vec![0.1; d]).collect();
//! let frames: Vec<_> = xs.iter().enumerate()
//!     .filter_map(|(i, x)| proto.encode(&ctx, i as u64, x))
//!     .collect();
//!
//! // ...server decodes and averages
//! let mut acc = proto.new_accumulator();
//! for f in &frames { proto.accumulate(&ctx, f, &mut acc).unwrap(); }
//! let mean = proto.finish(&ctx, acc, xs.len());
//! ```

pub mod apps;
pub mod bench;
pub mod cli;
pub mod coding;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod protocol;
pub mod report;
pub mod rng;
pub mod rotation;
pub mod runtime;
pub mod stats;
pub mod testkit;

pub use protocol::{Accumulator, Frame, Protocol, RoundCtx};
