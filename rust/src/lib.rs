//! # dme — Distributed Mean Estimation with Limited Communication
//!
//! A production-grade reproduction of Suresh, Yu, Kumar, McMahan,
//! *Distributed Mean Estimation with Limited Communication* (ICML 2017),
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the communication protocols with bit-exact
//!   encoders/decoders, a leader/worker coordinator, the application
//!   drivers (distributed Lloyd's, distributed power iteration), and the
//!   bench harness that regenerates every figure in the paper.
//! * **L2/L1 (python/, build-time only)** — JAX graphs + Pallas kernels
//!   for the numeric hot-spots (Hadamard rotation, stochastic k-level
//!   quantization), AOT-lowered to HLO text in `artifacts/` and executed
//!   from Rust via PJRT ([`runtime`]). Python never runs on the request
//!   path.
//!
//! ## Protocols (paper section → module)
//!
//! | π | paper | module |
//! |---|-------|--------|
//! | `π_sb` stochastic binary | §2.1 | [`protocol::binary`] |
//! | `π_sk` stochastic k-level | §2.2 | [`protocol::klevel`] |
//! | `π_srk` stochastic rotated | §3 | [`protocol::rotated`] |
//! | `π_svk` variable-length coded | §4 | [`protocol::varlen`] |
//! | `π_p` client sampling | §5 | [`protocol::sampling`] |
//!
//! ## Quickstart: a round session
//!
//! Every round follows the **prepare → encode → accumulate → finish**
//! lifecycle (see [`protocol`]): shared per-round state (e.g. the π_srk
//! rotation) is prepared exactly once, clients encode through a reusable
//! [`Encoder`], and the server folds frames through a streaming
//! [`Decoder`].
//!
//! ```no_run
//! use dme::protocol::{Decoder, Encoder, Protocol, RoundCtx, config::ProtocolConfig};
//!
//! let d = 256;
//! let cfg = ProtocolConfig::rotated(d, 16);
//! let proto = cfg.build().unwrap();
//! let ctx = RoundCtx::new(/*round=*/ 0, /*seed=*/ 42);
//!
//! // prepare once per round: the rotation is sampled here and only here
//! let state = proto.prepare(&ctx);
//!
//! // clients encode through one reusable encoder...
//! let xs: Vec<Vec<f32>> = (0..10).map(|_| vec![0.1; d]).collect();
//! let mut enc = Encoder::new(proto.as_ref(), &state);
//! let mut dec = Decoder::new(proto.as_ref(), &state);
//! for (i, x) in xs.iter().enumerate() {
//!     if let Some(frame) = enc.encode(i as u64, x) {
//!         // ...and the server streams the frames into one accumulator
//!         dec.push(&frame).unwrap();
//!     }
//! }
//! let mean = dec.finish(xs.len());
//! ```
//!
//! For the common "one full round" case use [`protocol::run_round`], or
//! [`protocol::run_round_par`] to shard clients across threads — the two
//! are bit-identical for every thread count (the f32 accumulation order
//! is fixed by client id, never by scheduling).
//!
//! ## Choosing a spec: the rate-control tier
//!
//! [`rate`] turns the paper's MSE-vs-communication theorems into an
//! optimizer: analytic + calibrated predictors per protocol kind
//! ([`rate::model`]), a bit-budget planner that enumerates the spec
//! space and returns the Pareto frontier ([`rate::planner::Plan`],
//! `dme tune`), and a live controller that can switch the session's
//! protocol **between rounds** over the versioned tag-5 `SpecChange`
//! message (`dme serve --auto-rate`) — with post-switch rounds
//! bit-identical to a fresh session started at the new spec.
//!
//! ## Scaling out: the aggregation tier
//!
//! The estimators are linear in the client frames, so server-side
//! aggregation distributes: [`coordinator::topology::Topology`] arranges
//! workers → [`coordinator::aggregator::Aggregator`]s → leader in
//! arbitrary-depth trees, each node folding its span into exactly
//! mergeable [`SlotPartial`]s (fixed-point sums, [`protocol::exact`]).
//! Root ingest drops from O(n · frames) to O(root-fan-in · slots) while
//! the root estimate stays **bit-identical to the flat topology for
//! every tree shape** — see `coordinator` for the tier model.
//!
//! ## Stress-testing the theory: the scenario engine
//!
//! [`scenario`] replays deterministic, seeded churn / straggler /
//! disconnect / flap fault plans over the real stack (`dme simulate`):
//! partial-round barriers finalize from the surviving clients as the
//! Lemma 8 estimator at the observed participation p̂, and every round's
//! measured error is recorded against the calibrated Lemma 8 prediction.

pub mod apps;
pub mod bench;
pub mod cli;
pub mod coding;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod protocol;
pub mod rate;
pub mod report;
pub mod rng;
pub mod rotation;
pub mod runtime;
pub mod scenario;
pub mod simd;
pub mod stats;
pub mod testkit;

pub use protocol::{
    run_round, run_round_par, run_round_with_scratch, Accumulator, Decoder, EncodeScratch,
    Encoder, Frame, Protocol, RoundCtx, RoundState, SlotPartial,
};
