//! Deterministic, seeded fault plans: who misbehaves, how, and when.
//!
//! A [`FaultPlan`] is a pure function `(round, client) → FaultAction`
//! derived from the experiment seed alone — no wall clock, no OS
//! entropy — so the same `--seed` replays the same churn bit for bit.
//! The grammar is a comma-separated list of clauses:
//!
//! ```text
//! drop=0.2                 # per-round dropout probability
//! disconnect=0.05          # per-round mid-round hangup probability
//! straggle=0.1:80ms        # straggler probability : max injected delay
//! flap=3                   # every 3rd round one whole aggregator span
//!                          # goes dark (BarrierTimeout skip + recovery)
//! ```
//!
//! e.g. `--faults drop=0.2,straggle=0.1:80ms,flap=3`.

use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::rng::{self, Pcg64};

/// Domain-separation tag for fault coins (vs data/protocol streams).
const FAULT_TAG: u64 = 0xFA17_7C01;

/// What one client does with one round's `RoundStart`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Encode and upload normally.
    Answer,
    /// Stay silent this round (the connection survives): the barrier
    /// must time out on this client — per-round churn.
    Drop,
    /// Hang up the connection: a mid-round disconnect. Permanent for
    /// the scenario's swarm clients (no reconnect), so disconnects
    /// accumulate across rounds.
    Disconnect,
    /// Sleep this long, then answer — a straggler racing the barrier
    /// deadline. Bounded by the plan's `straggle_max`.
    Straggle(Duration),
}

/// A seeded fault plan over `(round, client)`.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Per-round probability a client stays silent.
    pub dropout: f64,
    /// Per-round probability a client hangs up instead of answering.
    pub disconnect: f64,
    /// Per-round probability a client straggles.
    pub straggle: f64,
    /// Upper bound of the injected straggler delay; the realized delay
    /// is uniform in `[straggle_max/2, straggle_max)`.
    pub straggle_max: Duration,
    /// Every `flap_every`-th round, one whole aggregator span goes dark
    /// (rotating through the spans); 0 disables flapping.
    pub flap_every: u64,
    /// Seed for the fault coins (the scenario's `--seed`).
    pub seed: u64,
    /// The aggregator spans a flap can black out, set by the runner
    /// from the topology (empty = flat, flapping has no spans to kill).
    flap_spans: Vec<(u64, u64)>,
}

impl FaultPlan {
    /// A plan with no faults at all (every client answers).
    pub fn none(seed: u64) -> Self {
        FaultPlan {
            dropout: 0.0,
            disconnect: 0.0,
            straggle: 0.0,
            straggle_max: Duration::ZERO,
            flap_every: 0,
            seed,
            flap_spans: Vec::new(),
        }
    }

    /// Parse the fault-plan grammar (see the module docs).
    pub fn parse(spec: &str, seed: u64) -> Result<Self> {
        let mut plan = FaultPlan::none(seed);
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            let (key, value) = clause
                .split_once('=')
                .with_context(|| format!("fault clause `{clause}` is not key=value"))?;
            match key {
                "drop" => plan.dropout = parse_prob(value, "drop")?,
                "disconnect" => plan.disconnect = parse_prob(value, "disconnect")?,
                "straggle" => {
                    let (p, delay) = value.split_once(':').with_context(|| {
                        format!("straggle clause `{value}` wants prob:delay, e.g. 0.1:80ms")
                    })?;
                    plan.straggle = parse_prob(p, "straggle")?;
                    plan.straggle_max = parse_millis(delay)?;
                }
                "flap" => {
                    plan.flap_every = value
                        .parse()
                        .with_context(|| format!("flap period `{value}` is not an integer"))?;
                    ensure!(plan.flap_every > 0, "flap period must be >= 1");
                }
                other => bail!(
                    "unknown fault clause `{other}` (expected drop, disconnect, straggle, flap)"
                ),
            }
        }
        ensure!(
            plan.dropout + plan.disconnect + plan.straggle <= 1.0 + 1e-9,
            "fault probabilities sum to {:.3} > 1",
            plan.dropout + plan.disconnect + plan.straggle
        );
        Ok(plan)
    }

    /// Tell the plan which aggregator spans exist, so `flap=K` has
    /// something to black out (the runner calls this from the topology).
    pub fn with_flap_spans(mut self, spans: Vec<(u64, u64)>) -> Self {
        self.flap_spans = spans;
        self
    }

    /// The deterministic verdict for `(round, client)`. Coins are drawn
    /// in a fixed order (disconnect, drop, straggle — disjoint slices
    /// of one uniform draw) from a stream keyed by
    /// `(seed, FAULT_TAG, round, client)`, so verdicts are independent
    /// across clients and rounds yet bit-reproducible for a seed.
    pub fn decide(&self, round: u64, client: u64) -> FaultAction {
        // A flapped span drops wholesale — its aggregator sees an empty
        // barrier, takes the BarrierTimeout skip, and recovers next
        // round. Spans rotate so every aggregator gets its turn.
        if self.flap_every > 0 && !self.flap_spans.is_empty() && round % self.flap_every == 0 {
            let idx = (round / self.flap_every) as usize % self.flap_spans.len();
            let (lo, hi) = self.flap_spans[idx];
            if (lo..hi).contains(&client) {
                return FaultAction::Drop;
            }
        }
        let mut coins = Pcg64::new(rng::mix(&[self.seed, FAULT_TAG, round, client]));
        let u = coins.next_f64();
        if u < self.disconnect {
            return FaultAction::Disconnect;
        }
        if u < self.disconnect + self.dropout {
            return FaultAction::Drop;
        }
        if u < self.disconnect + self.dropout + self.straggle {
            // Uniform in [max/2, max): long enough to matter, bounded
            // so the scenario's wall clock stays bounded too.
            let frac = 0.5 + 0.5 * coins.next_f64();
            return FaultAction::Straggle(self.straggle_max.mul_f64(frac));
        }
        FaultAction::Answer
    }
}

fn parse_prob(s: &str, what: &str) -> Result<f64> {
    let p: f64 =
        s.parse().with_context(|| format!("{what} probability `{s}` is not a number"))?;
    ensure!((0.0..=1.0).contains(&p), "{what} probability {p} outside [0, 1]");
    Ok(p)
}

fn parse_millis(s: &str) -> Result<Duration> {
    let digits = s.strip_suffix("ms").unwrap_or(s);
    let ms: u64 = digits
        .parse()
        .with_context(|| format!("delay `{s}` is not of the form <millis>ms"))?;
    Ok(Duration::from_millis(ms))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_round_trips() {
        let p = FaultPlan::parse("drop=0.2, disconnect=0.05,straggle=0.1:80ms,flap=3", 7).unwrap();
        assert_eq!(p.dropout, 0.2);
        assert_eq!(p.disconnect, 0.05);
        assert_eq!(p.straggle, 0.1);
        assert_eq!(p.straggle_max, Duration::from_millis(80));
        assert_eq!(p.flap_every, 3);
        assert_eq!(p.seed, 7);
    }

    #[test]
    fn grammar_rejects_nonsense() {
        assert!(FaultPlan::parse("drop=1.5", 0).is_err());
        assert!(FaultPlan::parse("straggle=0.1", 0).is_err());
        assert!(FaultPlan::parse("flap=0", 0).is_err());
        assert!(FaultPlan::parse("warp=0.1", 0).is_err());
        assert!(FaultPlan::parse("drop=0.6,disconnect=0.6", 0).is_err());
    }

    #[test]
    fn decisions_are_seed_deterministic() {
        let p = FaultPlan::parse("drop=0.3,straggle=0.2:40ms", 42).unwrap();
        let q = FaultPlan::parse("drop=0.3,straggle=0.2:40ms", 42).unwrap();
        let r = FaultPlan::parse("drop=0.3,straggle=0.2:40ms", 43).unwrap();
        let mut differs = false;
        for round in 0..8 {
            for client in 0..64 {
                assert_eq!(p.decide(round, client), q.decide(round, client));
                differs |= p.decide(round, client) != r.decide(round, client);
            }
        }
        assert!(differs, "seed must change the plan");
    }

    #[test]
    fn dropout_rate_is_roughly_honored() {
        let p = FaultPlan::parse("drop=0.2", 11).unwrap();
        let n = 2000u64;
        let dropped = (0..n).filter(|&c| p.decide(0, c) == FaultAction::Drop).count();
        let rate = dropped as f64 / n as f64;
        assert!((rate - 0.2).abs() < 0.05, "dropout rate {rate} far from 0.2");
    }

    #[test]
    fn flap_blacks_out_whole_spans_in_rotation() {
        let p = FaultPlan::parse("flap=2", 5)
            .unwrap()
            .with_flap_spans(vec![(0, 8), (8, 16)]);
        // Round 0 flaps span 0, round 2 flaps span 1, odd rounds none.
        for c in 0..8 {
            assert_eq!(p.decide(0, c), FaultAction::Drop);
            assert_eq!(p.decide(1, c), FaultAction::Answer);
        }
        for c in 8..16 {
            assert_eq!(p.decide(0, c), FaultAction::Answer);
            assert_eq!(p.decide(2, c), FaultAction::Drop);
        }
    }

    #[test]
    fn straggle_delays_stay_bounded() {
        let p = FaultPlan::parse("straggle=1.0:100ms", 3).unwrap();
        for c in 0..200 {
            match p.decide(0, c) {
                FaultAction::Straggle(d) => {
                    assert!(d >= Duration::from_millis(50) && d < Duration::from_millis(100));
                }
                other => panic!("client {c}: expected a straggle, got {other:?}"),
            }
        }
    }
}
