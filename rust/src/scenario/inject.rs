//! The fault-injecting swarm client: protocol-correct [`Worker`]
//! encodes driven through [`Swarm::spawn_actions`], with the
//! [`FaultPlan`] deciding per `(round, client)` whether to answer,
//! stay silent, hang up, or straggle.
//!
//! One driver thread hosts the whole population (the swarm design), so
//! an injected straggler delay blocks that thread — which is exactly
//! the observable effect wanted: the *entire* cohort behind that swarm
//! arrives late, racing the parent's barrier deadline. Delays are
//! bounded by the plan's `straggle_max`, so a scenario's wall clock
//! stays bounded too.

use std::net::SocketAddr;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::swarm::{Swarm, SwarmAction};
use crate::coordinator::transport::{Envelope, Message};
use crate::coordinator::worker::{mean_update, Worker};
use crate::protocol::{EncodeScratch, Protocol};

use super::data::{client_vector, DataPlan};
use super::plan::{FaultAction, FaultPlan};

/// Spawn the swarm for clients `[base_id, base_id + n)` against `addr`,
/// each holding its scenario data vector and answering rounds through
/// the real `Worker` encode path under `faults`. `SpecChange` rebuilds
/// every client's protocol (the tag-5 contract); `Shutdown` closes the
/// connection (handled by the swarm driver itself).
#[allow(clippy::too_many_arguments)]
pub fn spawn_fault_swarm(
    addr: SocketAddr,
    base_id: u64,
    n: usize,
    protocol: Arc<dyn Protocol>,
    seed: u64,
    dim: usize,
    faults: FaultPlan,
    data: DataPlan,
) -> Result<Swarm> {
    // Per-client worker state, indexed by swarm slot (client id is
    // base_id + slot). Shard = the client's one scenario vector; the
    // mean update transmits it with weight 1 — plain distributed mean
    // estimation, the paper's core task.
    let mut workers: Vec<Worker> = (0..n as u64)
        .map(|i| Worker {
            client_id: base_id + i,
            shard: vec![client_vector(data, seed, base_id + i, dim)],
            protocol: protocol.clone(),
            update: mean_update(),
            seed,
        })
        .collect();
    let mut scratch = EncodeScratch::default();
    Swarm::spawn_actions(addr, n, 1, move |slot, env: &Envelope| {
        let worker = &mut workers[slot];
        match &env.msg {
            Message::RoundStart { round, shared_seed, dim, payload } => {
                let verdict = faults.decide(*round, worker.client_id);
                if verdict == FaultAction::Drop {
                    return SwarmAction::Silent;
                }
                if verdict == FaultAction::Disconnect {
                    return SwarmAction::Hangup;
                }
                if let FaultAction::Straggle(delay) = verdict {
                    // Serializes the driver thread on purpose: the
                    // whole cohort behind this swarm straggles.
                    std::thread::sleep(delay);
                }
                match worker
                    .step_seeded(env.session, *round, *shared_seed, *dim, payload, &mut scratch)
                {
                    Ok(reply) => SwarmAction::Reply(Envelope { session: env.session, msg: reply }),
                    // An encode failure is a scenario bug; hanging up
                    // surfaces it at the parent instead of deadlocking.
                    Err(_) => SwarmAction::Hangup,
                }
            }
            Message::SpecChange { spec, .. } => match worker.apply_spec(spec) {
                Ok(()) => SwarmAction::Silent,
                Err(_) => SwarmAction::Hangup,
            },
            // Upstream-only (or driver-handled) messages: ignore.
            _ => SwarmAction::Silent,
        }
    })
}
