//! Heterogeneous client data generators for scenarios.
//!
//! Every client's vector is a pure function of `(seed, plan, client)`,
//! so the population — and therefore the true mean a scenario's MSE is
//! measured against — replays bit for bit under the same `--seed`.
//! `iid` is the homogeneous baseline; the other plans break the IID
//! assumption in the ways federated populations actually do (per-client
//! mean shift, per-client scale, multi-modal clusters), which is what
//! makes partial rounds *interesting*: dropping clients from a skewed
//! population moves the estimate, and Lemma 8's variance term prices
//! exactly that.

use anyhow::{bail, Result};

use crate::rng::{self, Pcg64};

/// Domain-separation tag for data streams (vs fault/protocol streams).
const DATA_TAG: u64 = 0xDA7A_5EED;

/// How the scenario population's vectors are distributed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataPlan {
    /// Homogeneous: every client draws N(0, 1/d) coordinates
    /// (‖x‖ ≈ 1).
    Iid,
    /// Non-IID mean shift: client c adds a spike on coordinate
    /// `c mod d` — each client pulls the mean its own way.
    Shifted,
    /// Heterogeneous norms: client c scales its IID draw by a factor
    /// cycling through {0.25, 0.75, 1.25, 1.75} — the unbalanced-norm
    /// regime the paper's Figure 1 stresses.
    Scaled,
    /// Four cluster centers (drawn once from the seed); client c sits
    /// near center `c mod 4` — a multi-modal population where churn
    /// can silence a whole mode.
    Clustered,
}

impl DataPlan {
    /// Parse a plan name (`iid`, `shifted`, `scaled`, `clustered`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "iid" => DataPlan::Iid,
            "shifted" => DataPlan::Shifted,
            "scaled" => DataPlan::Scaled,
            "clustered" => DataPlan::Clustered,
            other => bail!(
                "unknown data plan `{other}` (expected iid, shifted, scaled, clustered)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DataPlan::Iid => "iid",
            DataPlan::Shifted => "shifted",
            DataPlan::Scaled => "scaled",
            DataPlan::Clustered => "clustered",
        }
    }
}

/// Client `client`'s local vector under `plan` — deterministic in
/// `(seed, plan, client)` and independent across clients.
pub fn client_vector(plan: DataPlan, seed: u64, client: u64, dim: usize) -> Vec<f32> {
    let mut rng = Pcg64::new(rng::mix(&[seed, DATA_TAG, plan as u64, client]));
    let inv_sqrt_d = 1.0 / (dim as f32).sqrt();
    let mut x = vec![0.0f32; dim];
    rng.fill_gaussian_f32(&mut x);
    for v in x.iter_mut() {
        *v *= inv_sqrt_d;
    }
    match plan {
        DataPlan::Iid => {}
        DataPlan::Shifted => {
            x[(client % dim as u64) as usize] += 1.0;
        }
        DataPlan::Scaled => {
            let scale = 0.25 + 0.5 * (client % 4) as f32;
            for v in x.iter_mut() {
                *v *= scale;
            }
        }
        DataPlan::Clustered => {
            // Centers are a function of the seed alone, shared by every
            // client; noise stays per-client.
            let mut centers = Pcg64::new(rng::mix(&[seed, DATA_TAG, u64::MAX]));
            let mode = (client % 4) as usize;
            for k in 0..4 {
                let mut c = vec![0.0f32; dim];
                centers.fill_gaussian_f32(&mut c);
                if k == mode {
                    for (v, ci) in x.iter_mut().zip(&c) {
                        *v = 0.1 * *v + ci * inv_sqrt_d;
                    }
                }
            }
        }
    }
    x
}

/// The whole population's vectors, client id order.
pub fn population(plan: DataPlan, seed: u64, n: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..n as u64).map(|c| client_vector(plan, seed, c, dim)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vectors_replay_per_seed_and_differ_across_clients() {
        for plan in [DataPlan::Iid, DataPlan::Shifted, DataPlan::Scaled, DataPlan::Clustered] {
            let a = client_vector(plan, 9, 3, 32);
            let b = client_vector(plan, 9, 3, 32);
            let c = client_vector(plan, 9, 4, 32);
            let d = client_vector(plan, 10, 3, 32);
            assert_eq!(a, b, "{plan:?}: same (seed, client) must replay");
            assert_ne!(a, c, "{plan:?}: clients must differ");
            assert_ne!(a, d, "{plan:?}: seeds must differ");
        }
    }

    #[test]
    fn scaled_plan_produces_heterogeneous_norms() {
        let pop = population(DataPlan::Scaled, 4, 8, 64);
        let norm = |v: &[f32]| v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        // Clients 0 and 3 sit on scale 0.25 vs 1.75: a 7x norm ratio.
        assert!(norm(&pop[3]) > 3.0 * norm(&pop[0]));
    }

    #[test]
    fn clustered_plan_groups_modes() {
        let pop = population(DataPlan::Clustered, 8, 8, 64);
        let dist = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>()
        };
        // Same mode (0 and 4) much closer than different modes (0 and 1).
        assert!(dist(&pop[0], &pop[4]) < dist(&pop[0], &pop[1]));
    }

    #[test]
    fn parse_names() {
        assert_eq!(DataPlan::parse("iid").unwrap(), DataPlan::Iid);
        assert_eq!(DataPlan::parse("clustered").unwrap(), DataPlan::Clustered);
        assert!(DataPlan::parse("zipf").is_err());
    }
}
