//! Scenario engine: deterministic churn, stragglers, and Lemma 8
//! partial rounds over the **real** stack (`dme simulate`).
//!
//! The paper's analysis assumes every client reports every round. Real
//! federated cohorts do not: clients drop, disconnect, straggle, and
//! whole aggregation subtrees flap. This module turns those failure
//! modes into *replayable experiments* — no mocks, no simulated
//! transport: scenarios run swarm TCP clients against the same
//! `HubBinding` → [`Leader`](crate::coordinator::Leader) /
//! [`Aggregator`](crate::coordinator::Aggregator) machinery `dme serve`
//! deploys, with faults injected at the client edge.
//!
//! # The pieces
//!
//! * [`plan`] — the seeded fault plan: a pure function
//!   `(round, client) → {Answer, Drop, Disconnect, Straggle(delay)}`
//!   parsed from the grammar `drop=P,disconnect=P,straggle=P:MSms,
//!   flap=K`. Same seed, same churn, bit for bit.
//! * [`data`] — deterministic client populations: `iid`, `shifted`,
//!   `scaled`, `clustered` — the non-IID shapes that make losing
//!   clients *cost* something.
//! * [`inject`] (Linux) — the fault-injecting swarm: protocol-correct
//!   `Worker` encodes driven through `Swarm::spawn_actions`, with the
//!   plan's verdict deciding answer / silence / hangup / delay.
//! * [`run`] (Linux) — the runner: builds flat or depth-2 trees with
//!   [`BarrierPolicy::Partial`](crate::coordinator::BarrierPolicy) at
//!   every barrier node, and emits one trajectory row per round.
//!
//! # Lemma 8, operationally
//!
//! When a partial-round barrier finalizes from the surviving set `S`,
//! the estimate is the Lemma 8 sampled-mean estimator instantiated at
//! the *observed* rate p̂ = |S|/n (the exact fold divides by the
//! per-slot contributor count, which **is** n·p̂ = |S| — see
//! `coordinator::leader`'s module docs). Each trajectory row therefore
//! carries both the measured squared error and the calibrated Lemma 8
//! prediction at that round's p̂
//! (`rate::model::mse_with_participation`):
//!
//! ```text
//! E(π_p̂) = E(π)/p̂ + (1 − p̂)/(n·p̂) · avg‖X‖²      (PAPER.md, Lemma 8)
//! ```
//!
//! so a scenario is simultaneously a robustness test (every round
//! completes) and a conformance test (the error stays within
//! [`run::MSE_SLACK`] of the theory).
//!
//! # Determinism
//!
//! Everything a scenario draws — fault coins, client vectors, protocol
//! randomness — is keyed by the one `--seed`, which is why `dme
//! simulate` refuses to run without it. Trajectory `rows` replay bit
//! for bit for drop/disconnect/flap plans; straggler survival races the
//! real barrier deadline by design (see [`run`]'s module docs), and
//! per-round wall clock is reported outside the replay contract.

pub mod data;
#[cfg(target_os = "linux")]
pub mod inject;
pub mod plan;
#[cfg(target_os = "linux")]
pub mod run;

pub use data::DataPlan;
pub use plan::{FaultAction, FaultPlan};
#[cfg(target_os = "linux")]
pub use run::{
    builtin_matrix, run_matrix, run_scenario, scenarios_json, write_scenarios_json, ScenarioSpec,
    Trajectory, TrajectoryRow,
};
