//! The scenario runner: real transports, real barriers, injected
//! faults, and a per-round trajectory against the Lemma 8 prediction.
//!
//! A [`ScenarioSpec`] names a topology (flat, or depth-2 with `fanout`
//! aggregators over contiguous client spans), a TCP transport, a
//! protocol spec, a [`FaultPlan`], and a [`DataPlan`] — all keyed by
//! one seed. [`run_scenario`] stands the tree up exactly the way
//! `dme serve`/`dme aggregate` would (swarm clients → `HubBinding` →
//! `Leader`/`Aggregator`), runs it with
//! [`BarrierPolicy::Partial`] at every barrier node, and records one
//! [`TrajectoryRow`] per round: observed participation p̂, squared
//! error of the partial-round estimate against the *full* population's
//! true mean, and the calibrated Lemma 8 prediction at that p̂
//! ([`model::mse_with_participation`]).
//!
//! # Determinism contract
//!
//! `rows` replays bit for bit for a given spec + seed when the fault
//! plan is made of `drop`/`disconnect`/`flap` clauses: the survivor set
//! is a pure function of the seed, and the exact fold makes the
//! estimate independent of arrival order and decode-thread count.
//! `straggle` clauses intentionally race the barrier deadline, so a
//! straggler's survival is a wall-clock fact, not a seeded one — keep
//! `straggle_max` well under the round timeout when replay matters.
//! `wall_ms` is measured wall clock and is *never* part of the replay
//! contract, which is why it lives beside `rows`, not inside them.

use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::coordinator::transport::{HubBinding, TcpEndpoint, Transport};
use crate::coordinator::{Aggregator, BarrierPolicy, ChildKey, Leader};
use crate::protocol::config::ProtocolConfig;
use crate::rate::model::{self, Calibration};
use crate::stats;

use super::data::{self, DataPlan};
use super::inject::spawn_fault_swarm;
use super::plan::FaultPlan;

/// Slack factor for [`Trajectory::check_slack`]: the mean measured MSE
/// across a scenario's rounds must stay within this multiple of the
/// mean calibrated Lemma 8 prediction. The predictions are upper
/// bounds, so measured values usually sit *below* 1x; the slack absorbs
/// the chi-square noise of averaging a handful of rounds.
pub const MSE_SLACK: f64 = 3.0;

/// Env var holding a hard wall-clock budget (milliseconds) for a whole
/// [`run_matrix`] call — the CI leg sets it so a hung scenario fails
/// loudly instead of eating the job's timeout.
pub const BUDGET_ENV: &str = "DME_SCENARIO_BUDGET_MS";

/// Everything one scenario needs, all derived from CLI flags + seed.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    /// Scenario name (trajectory key in the JSON output).
    pub name: String,
    /// Protocol spec string, e.g. `rotated:k=16`.
    pub protocol: String,
    pub n_clients: usize,
    pub dim: usize,
    /// 0 or 1 = flat (clients connect straight to the leader);
    /// otherwise the number of depth-2 aggregators over uniform
    /// contiguous spans.
    pub fanout: usize,
    pub rounds: u64,
    /// Barrier deadline at the tier closest to the clients. In depth-2
    /// mode the leader waits `2 * timeout + 250ms` so a child tier's
    /// partial finalization (or flap skip) resolves before the root
    /// gives up on that span.
    pub timeout: Duration,
    pub transport: Transport,
    pub decode_threads: usize,
    pub faults: FaultPlan,
    pub data: DataPlan,
    pub seed: u64,
}

/// One round of a scenario: the deterministic part of the trajectory.
#[derive(Clone, Debug, PartialEq)]
pub struct TrajectoryRow {
    pub round: u64,
    /// Observed participation p̂ = |S| / n for this round.
    pub participation: f64,
    /// Late same-round duplicates dropped by the barrier.
    pub duplicate_uploads: u64,
    /// Squared error of the (p̂-rescaled) estimate against the full
    /// population's true mean.
    pub sq_error: f64,
    /// Calibrated Lemma 8 prediction at the observed p̂.
    pub predicted_mse: f64,
    /// Exact uplink payload bits the surviving clients spent.
    pub uplink_bits: u64,
}

/// A finished scenario: config echo + per-round rows + wall clock.
#[derive(Clone, Debug)]
pub struct Trajectory {
    pub name: String,
    pub protocol: String,
    pub seed: u64,
    pub n_clients: usize,
    pub dim: usize,
    pub fanout: usize,
    pub transport: String,
    pub data: String,
    pub faults: String,
    pub slack: f64,
    pub rows: Vec<TrajectoryRow>,
    /// Per-round wall clock (ms). Measured, excluded from the replay
    /// determinism contract — deliberately kept out of `rows`.
    pub wall_ms: Vec<f64>,
}

impl Trajectory {
    /// Mean measured squared error over rounds (NaN rows excluded).
    pub fn mean_measured_mse(&self) -> f64 {
        mean_of(self.rows.iter().map(|r| r.sq_error))
    }

    /// Mean calibrated Lemma 8 prediction over rounds.
    pub fn mean_predicted_mse(&self) -> f64 {
        mean_of(self.rows.iter().map(|r| r.predicted_mse))
    }

    /// Mean observed participation over rounds.
    pub fn mean_participation(&self) -> f64 {
        mean_of(self.rows.iter().map(|r| r.participation))
    }

    /// Fail if the measured MSE blew past `slack` times the calibrated
    /// Lemma 8 prediction. Lossless specs predict ~0 and are exempt —
    /// there is no meaningful bound to hold them to.
    pub fn check_slack(&self) -> Result<()> {
        let measured = self.mean_measured_mse();
        let predicted = self.mean_predicted_mse();
        if !measured.is_finite() || !predicted.is_finite() || predicted < 1e-12 {
            return Ok(());
        }
        ensure!(
            measured <= self.slack * predicted,
            "scenario `{}`: measured MSE {measured:.3e} exceeds {}x the calibrated \
             Lemma 8 prediction {predicted:.3e}",
            self.name,
            self.slack
        );
        Ok(())
    }
}

fn mean_of(vals: impl Iterator<Item = f64>) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for v in vals {
        if v.is_finite() {
            sum += v;
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        sum / n as f64
    }
}

/// Run one scenario over the real stack and return its trajectory.
pub fn run_scenario(spec: &ScenarioSpec) -> Result<Trajectory> {
    ensure!(spec.n_clients >= 1, "scenario `{}` needs at least one client", spec.name);
    ensure!(spec.rounds >= 1, "scenario `{}` needs at least one round", spec.name);
    ensure!(
        spec.fanout <= spec.n_clients,
        "scenario `{}`: fanout {} exceeds {} clients",
        spec.name,
        spec.fanout,
        spec.n_clients
    );
    let cfg = ProtocolConfig::parse(&spec.protocol, spec.dim)
        .with_context(|| format!("scenario `{}` protocol spec", spec.name))?;
    let protocol = cfg.build()?;

    // The measurement frame: the same deterministic population the
    // swarm clients hold, so `sq_error` is against the true full-cohort
    // mean — partial rounds are charged for the clients they lost.
    let population = data::population(spec.data, spec.seed, spec.n_clients, spec.dim);
    let truth = stats::true_mean(&population);
    let avg_sq = stats::avg_norm_sq(&population);
    let mut cal = Calibration::new(spec.seed);
    cal.fit(&cfg)
        .with_context(|| format!("scenario `{}` calibration", spec.name))?;
    let base_pred = cal.predicted_mse(&cfg, spec.n_clients, avg_sq);

    // Stand the tree up. `agg_threads` keeps the depth-2 plumbing alive
    // until shutdown; `swarms` are joined last, after every Shutdown
    // has propagated.
    let n = spec.n_clients;
    let mut swarms = Vec::new();
    let mut agg_threads = Vec::new();
    let mut leader = if spec.fanout <= 1 {
        let binding = HubBinding::bind(spec.transport, "127.0.0.1:0")?;
        let addr = binding.local_addr()?;
        swarms.push(spawn_fault_swarm(
            addr,
            0,
            n,
            protocol.clone(),
            spec.seed,
            spec.dim,
            spec.faults.clone(),
            spec.data,
        )?);
        let hub = binding.accept(n)?;
        let expected = (0..n as u64).map(ChildKey::Client).collect();
        Leader::new(protocol.clone(), hub, spec.seed)
            .with_decode_threads(spec.decode_threads)
            .with_round_timeout(spec.timeout)
            .with_expected_children(expected)
            .with_barrier_policy(BarrierPolicy::Partial)
    } else {
        ensure!(
            n % spec.fanout == 0,
            "scenario `{}`: {} clients do not split into {} uniform spans",
            spec.name,
            n,
            spec.fanout
        );
        let span_len = n / spec.fanout;
        let spans: Vec<(u64, u64)> = (0..spec.fanout)
            .map(|i| ((i * span_len) as u64, ((i + 1) * span_len) as u64))
            .collect();
        // Flap faults black out whole spans; the plan needs to know the
        // topology to aim at one.
        let faults = spec.faults.clone().with_flap_spans(spans.clone());
        let leader_binding = HubBinding::bind(spec.transport, "127.0.0.1:0")?;
        let leader_addr = leader_binding.local_addr()?.to_string();
        for (agg_id, &(lo, hi)) in spans.iter().enumerate() {
            let child_binding = HubBinding::bind(spec.transport, "127.0.0.1:0")?;
            let child_addr = child_binding.local_addr()?;
            swarms.push(spawn_fault_swarm(
                child_addr,
                lo,
                (hi - lo) as usize,
                protocol.clone(),
                spec.seed,
                spec.dim,
                faults.clone(),
                spec.data,
            )?);
            let proto = protocol.clone();
            let up_addr = leader_addr.clone();
            let (seed, threads, agg_timeout) = (spec.seed, spec.decode_threads, spec.timeout);
            let handle = std::thread::Builder::new()
                .name(format!("dme-scenario-agg{agg_id}"))
                .spawn(move || -> Result<()> {
                    let hub = child_binding.accept((hi - lo) as usize)?;
                    let mut up = TcpEndpoint::connect(&up_addr)?;
                    Aggregator::new(proto, seed, agg_id as u64, (lo, hi))
                        .with_level(0)
                        .with_decode_threads(threads)
                        .with_round_timeout(agg_timeout)
                        .with_barrier_policy(BarrierPolicy::Partial)
                        .run(hub, &mut up)?;
                    Ok(())
                })
                .context("spawning scenario aggregator")?;
            agg_threads.push(handle);
        }
        let hub = leader_binding.accept(spec.fanout)?;
        let expected = spans
            .iter()
            .enumerate()
            .map(|(i, &span)| ChildKey::Aggregator { id: i as u64, span })
            .collect();
        // The root waits out a full child-tier partial finalization (or
        // flap skip) plus margin before declaring a span gone.
        Leader::new(protocol.clone(), hub, spec.seed)
            .with_decode_threads(spec.decode_threads)
            .with_round_timeout(spec.timeout * 2 + Duration::from_millis(250))
            .with_expected_children(expected)
            .with_barrier_policy(BarrierPolicy::Partial)
    };

    let mut rows = Vec::with_capacity(spec.rounds as usize);
    let mut wall_ms = Vec::with_capacity(spec.rounds as usize);
    for r in 0..spec.rounds {
        let t0 = Instant::now();
        let out = leader
            .round(r, spec.dim as u32, &[])
            .with_context(|| format!("scenario `{}` round {r}", spec.name))?;
        wall_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        let m = leader.metrics().rounds.last().expect("round() pushes metrics");
        let sq_error = match out.means.first() {
            Some(est) => stats::sq_error(est, &truth),
            None => f64::NAN,
        };
        rows.push(TrajectoryRow {
            round: r,
            participation: m.participation,
            duplicate_uploads: m.duplicate_uploads,
            sq_error,
            predicted_mse: model::mse_with_participation(
                base_pred,
                spec.n_clients,
                avg_sq,
                m.participation,
            ),
            uplink_bits: m.uplink_bits,
        });
    }

    // Teardown, leniently: disconnect faults leave dead connections the
    // shutdown broadcast will trip over, but every hub stages Shutdown
    // to its live children before surfacing the dead ones — so the live
    // tree still winds down and the joins below terminate.
    if let Err(e) = leader.shutdown() {
        eprintln!("[scenario {}] shutdown saw departed children: {e:#}", spec.name);
    }
    for handle in agg_threads {
        match handle.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => eprintln!("[scenario {}] aggregator exited early: {e:#}", spec.name),
            Err(_) => eprintln!("[scenario {}] aggregator thread panicked", spec.name),
        }
    }
    for swarm in swarms {
        if let Err(e) = swarm.join() {
            eprintln!("[scenario {}] swarm: {e:#}", spec.name);
        }
    }

    Ok(Trajectory {
        name: spec.name.clone(),
        protocol: spec.protocol.clone(),
        seed: spec.seed,
        n_clients: spec.n_clients,
        dim: spec.dim,
        fanout: spec.fanout,
        transport: spec.transport.to_string(),
        data: spec.data.name().to_string(),
        faults: format!(
            "drop={},disconnect={},straggle={}:{}ms,flap={}",
            spec.faults.dropout,
            spec.faults.disconnect,
            spec.faults.straggle,
            spec.faults.straggle_max.as_millis(),
            spec.faults.flap_every,
        ),
        slack: MSE_SLACK,
        rows,
        wall_ms,
    })
}

/// Run a list of scenarios under the optional [`BUDGET_ENV`] wall-clock
/// budget, failing loudly (instead of silently truncating) if the
/// budget runs out before the matrix does.
pub fn run_matrix(specs: &[ScenarioSpec]) -> Result<Vec<Trajectory>> {
    let budget = match std::env::var(BUDGET_ENV) {
        Ok(v) => Some(Duration::from_millis(
            v.trim().parse().with_context(|| format!("{BUDGET_ENV}=`{v}` is not milliseconds"))?,
        )),
        Err(_) => None,
    };
    let t0 = Instant::now();
    let mut out = Vec::with_capacity(specs.len());
    for spec in specs {
        if let Some(b) = budget {
            ensure!(
                t0.elapsed() < b,
                "scenario budget {BUDGET_ENV}={}ms exhausted after {} of {} scenarios",
                b.as_millis(),
                out.len(),
                specs.len()
            );
        }
        out.push(run_scenario(spec)?);
    }
    Ok(out)
}

/// The built-in churn + straggler + flap + non-IID matrix `dme simulate
/// --matrix` (and the CI scenario leg) runs. Small on purpose: every
/// faulty round costs a barrier timeout, so wall clock scales with
/// `rounds * timeout`, not with work.
pub fn builtin_matrix(seed: u64) -> Result<Vec<ScenarioSpec>> {
    struct Row {
        name: &'static str,
        protocol: &'static str,
        n_clients: usize,
        fanout: usize,
        rounds: u64,
        timeout_ms: u64,
        transport: Transport,
        decode_threads: usize,
        faults: &'static str,
        data: &'static str,
    }
    let rows = [
        Row {
            name: "churn20-depth2-reactor",
            protocol: "rotated:k=16",
            n_clients: 24,
            fanout: 3,
            rounds: 4,
            timeout_ms: 200,
            transport: Transport::Reactor,
            decode_threads: 2,
            faults: "drop=0.2",
            data: "iid",
        },
        Row {
            name: "stragglers-flat-threads",
            protocol: "rotated:k=16",
            n_clients: 16,
            fanout: 0,
            rounds: 3,
            timeout_ms: 400,
            transport: Transport::Threads,
            decode_threads: 1,
            faults: "straggle=0.3:60ms",
            data: "iid",
        },
        Row {
            name: "flap-depth2-reactor",
            protocol: "klevel:k=8",
            n_clients: 24,
            fanout: 3,
            rounds: 4,
            timeout_ms: 150,
            transport: Transport::Reactor,
            decode_threads: 4,
            faults: "flap=2",
            data: "iid",
        },
        Row {
            name: "disconnect-flat-reactor",
            protocol: "rotated:k=16",
            n_clients: 16,
            fanout: 0,
            rounds: 3,
            timeout_ms: 200,
            transport: Transport::Reactor,
            decode_threads: 2,
            faults: "disconnect=0.1",
            data: "scaled",
        },
        Row {
            name: "noniid-churn-flat-threads",
            protocol: "binary",
            n_clients: 16,
            fanout: 0,
            rounds: 3,
            timeout_ms: 200,
            transport: Transport::Threads,
            decode_threads: 1,
            faults: "drop=0.25",
            data: "clustered",
        },
        // Frontier families under churn: DRIVE's shared rotation and the
        // correlated offset stream must survive partial rounds (dropped
        // clients leave their shared offsets unused, never mis-applied).
        Row {
            name: "churn-drive-flat-threads",
            protocol: "drive",
            n_clients: 16,
            fanout: 0,
            rounds: 3,
            timeout_ms: 200,
            transport: Transport::Threads,
            decode_threads: 1,
            faults: "drop=0.2",
            data: "iid",
        },
        Row {
            name: "correlated-churn-depth2-reactor",
            protocol: "correlated:k=8",
            n_clients: 24,
            fanout: 3,
            rounds: 3,
            timeout_ms: 200,
            transport: Transport::Reactor,
            decode_threads: 2,
            faults: "drop=0.2",
            data: "iid",
        },
    ];
    rows.iter()
        .map(|r| {
            Ok(ScenarioSpec {
                name: r.name.to_string(),
                protocol: r.protocol.to_string(),
                n_clients: r.n_clients,
                dim: 64,
                fanout: r.fanout,
                rounds: r.rounds,
                timeout: Duration::from_millis(r.timeout_ms),
                transport: r.transport,
                decode_threads: r.decode_threads,
                faults: FaultPlan::parse(r.faults, seed)?,
                data: DataPlan::parse(r.data)?,
                seed,
            })
        })
        .collect()
}

/// Serialize trajectories as the `BENCH_scenarios.json` document —
/// hand-rolled like `bench::Bench::to_json`, stable field order, `{:?}`
/// float formatting so identical runs produce identical bytes.
pub fn scenarios_json(trajectories: &[Trajectory]) -> String {
    let mut s = String::from("{\n  \"scenarios\": [\n");
    for (i, t) in trajectories.iter().enumerate() {
        s.push_str("    {\n");
        s.push_str(&format!("      \"name\": \"{}\",\n", esc(&t.name)));
        s.push_str(&format!("      \"protocol\": \"{}\",\n", esc(&t.protocol)));
        s.push_str(&format!("      \"seed\": {},\n", t.seed));
        s.push_str(&format!("      \"n_clients\": {},\n", t.n_clients));
        s.push_str(&format!("      \"dim\": {},\n", t.dim));
        s.push_str(&format!("      \"fanout\": {},\n", t.fanout));
        s.push_str(&format!("      \"transport\": \"{}\",\n", esc(&t.transport)));
        s.push_str(&format!("      \"data\": \"{}\",\n", esc(&t.data)));
        s.push_str(&format!("      \"faults\": \"{}\",\n", esc(&t.faults)));
        s.push_str(&format!("      \"slack\": {},\n", json_f64(t.slack)));
        s.push_str(&format!(
            "      \"mean_measured_mse\": {},\n",
            json_f64(t.mean_measured_mse())
        ));
        s.push_str(&format!(
            "      \"mean_predicted_mse\": {},\n",
            json_f64(t.mean_predicted_mse())
        ));
        s.push_str("      \"rows\": [\n");
        for (j, r) in t.rows.iter().enumerate() {
            s.push_str(&format!(
                "        {{\"round\": {}, \"participation\": {}, \"duplicate_uploads\": {}, \
                 \"sq_error\": {}, \"predicted_mse\": {}, \"uplink_bits\": {}}}{}\n",
                r.round,
                json_f64(r.participation),
                r.duplicate_uploads,
                json_f64(r.sq_error),
                json_f64(r.predicted_mse),
                r.uplink_bits,
                if j + 1 < t.rows.len() { "," } else { "" },
            ));
        }
        s.push_str("      ],\n");
        let walls: Vec<String> = t.wall_ms.iter().map(|&w| json_f64(w)).collect();
        s.push_str(&format!("      \"wall_ms\": [{}]\n", walls.join(", ")));
        s.push_str(&format!("    }}{}\n", if i + 1 < trajectories.len() { "," } else { "" }));
    }
    s.push_str("  ]\n}\n");
    s
}

/// Write [`scenarios_json`] to `path`.
pub fn write_scenarios_json(path: &str, trajectories: &[Trajectory]) -> Result<()> {
    std::fs::write(path, scenarios_json(trajectories)).with_context(|| format!("writing {path}"))
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:?}")
    } else {
        "null".to_string()
    }
}
