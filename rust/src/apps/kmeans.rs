//! Distributed Lloyd's algorithm (k-means) with quantized uplink —
//! the paper's Figure 2 experiment.
//!
//! Each round: the leader broadcasts the current centers; every client
//! assigns its local points to the nearest center, computes per-center
//! local means and counts, and uploads the means through the configured
//! mean-estimation protocol (counts travel as frame weights — the tiny
//! side-channel the paper also assumes). The leader forms the weighted
//! average per center. The tracked metric is the paper's y-axis: the
//! global k-means objective Σ_x min_c ‖x − c‖².

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::leader::{spawn_local_cluster, Leader};
use crate::coordinator::worker::UpdateFn;
use crate::linalg;
use crate::protocol::Protocol;
use crate::rng::Pcg64;

/// Configuration for a distributed k-means run.
#[derive(Clone, Debug)]
pub struct KMeansConfig {
    /// Number of centers (the paper uses 10).
    pub n_centers: usize,
    /// Number of clients (the paper uses 10).
    pub n_clients: usize,
    /// Lloyd iterations.
    pub iters: usize,
    /// Seed for center init and protocol randomness.
    pub seed: u64,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        KMeansConfig { n_centers: 10, n_clients: 10, iters: 10, seed: 17 }
    }
}

/// One iteration's record.
#[derive(Clone, Debug)]
pub struct KMeansRound {
    pub iter: usize,
    /// Global Lloyd objective after the update.
    pub objective: f64,
    /// Cumulative uplink bits so far.
    pub cum_bits: u64,
}

/// Full run result.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub rounds: Vec<KMeansRound>,
    pub centers: Vec<Vec<f32>>,
    /// Average uplink bits per data dimension per iteration (the paper's
    /// x-axis unit is cumulative bits/dimension).
    pub bits_per_dim_per_iter: f64,
}

/// Assign `x` to the nearest center.
pub fn nearest(x: &[f32], centers: &[Vec<f32>]) -> usize {
    let dists: Vec<f64> = centers.iter().map(|c| linalg::dist_sq(x, c)).collect();
    linalg::argmin(&dists)
}

/// Global k-means objective.
pub fn objective(data: &[Vec<f32>], centers: &[Vec<f32>]) -> f64 {
    data.iter()
        .map(|x| centers.iter().map(|c| linalg::dist_sq(x, c)).fold(f64::MAX, f64::min))
        .sum()
}

/// k-means++-style init (distance-weighted), deterministic in the seed.
pub fn init_centers(data: &[Vec<f32>], k: usize, seed: u64) -> Vec<Vec<f32>> {
    assert!(!data.is_empty() && k >= 1);
    let mut rng = Pcg64::new(crate::rng::mix(&[seed, 0x6b6d_6561_6e73]));
    let mut centers = vec![data[rng.next_below(data.len() as u32) as usize].clone()];
    let mut d2: Vec<f64> = data.iter().map(|x| linalg::dist_sq(x, &centers[0])).collect();
    while centers.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            rng.next_below(data.len() as u32) as usize
        } else {
            let mut target = rng.next_f64() * total;
            let mut idx = 0;
            for (i, &w) in d2.iter().enumerate() {
                target -= w;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        centers.push(data[next].clone());
        for (i, x) in data.iter().enumerate() {
            d2[i] = d2[i].min(linalg::dist_sq(x, &centers[centers.len() - 1]));
        }
    }
    centers
}

/// The Lloyd's worker update: assign local points, return per-center
/// (local mean, count). Empty clusters upload weight 0.
pub fn lloyd_update(n_centers: usize) -> UpdateFn {
    Arc::new(move |broadcast: &[f32], dim: u32, shard: &[Vec<f32>]| {
        let d = dim as usize;
        let centers: Vec<Vec<f32>> =
            broadcast.chunks_exact(d).map(|c| c.to_vec()).collect();
        debug_assert_eq!(centers.len(), n_centers);
        let mut sums = vec![vec![0.0f64; d]; n_centers];
        let mut counts = vec![0usize; n_centers];
        for x in shard {
            let c = nearest(x, &centers);
            counts[c] += 1;
            for (s, &v) in sums[c].iter_mut().zip(x) {
                *s += v as f64;
            }
        }
        (0..n_centers)
            .map(|c| {
                if counts[c] == 0 {
                    // Keep the old center with zero weight (silent slot).
                    (centers[c].clone(), 0.0)
                } else {
                    let inv = 1.0 / counts[c] as f64;
                    (
                        sums[c].iter().map(|&v| (v * inv) as f32).collect(),
                        counts[c] as f32,
                    )
                }
            })
            .collect()
    })
}

/// Run distributed Lloyd's over the coordinator with the given protocol.
/// `data` is sharded round-robin across `cfg.n_clients` workers.
pub fn run(
    data: &[Vec<f32>],
    protocol: Arc<dyn Protocol>,
    cfg: &KMeansConfig,
) -> Result<KMeansResult> {
    let d = protocol.dim();
    let shards = crate::data::Dataset::new("kmeans", data.to_vec()).shard(cfg.n_clients);
    let (mut leader, handles) =
        spawn_local_cluster(protocol, shards, lloyd_update(cfg.n_centers), cfg.seed);

    let mut centers = init_centers(data, cfg.n_centers, cfg.seed);
    let mut rounds = Vec::with_capacity(cfg.iters);
    let mut cum_bits = 0u64;
    for iter in 0..cfg.iters {
        let state: Vec<f32> = centers.iter().flatten().copied().collect();
        let out = leader.round(iter as u64, d as u32, &state)?;
        for (c, (mean, &w)) in centers.iter_mut().zip(out.means.iter().zip(&out.weights)) {
            if w > 0.0 {
                *c = mean.clone();
            }
        }
        cum_bits += out.uplink_bits;
        rounds.push(KMeansRound { iter, objective: objective(data, &centers), cum_bits });
    }
    shutdown(&mut leader, handles)?;
    let bits_per_dim_per_iter =
        cum_bits as f64 / (d as f64 * cfg.iters as f64);
    Ok(KMeansResult { rounds, centers, bits_per_dim_per_iter })
}

fn shutdown(
    leader: &mut Leader,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
) -> Result<()> {
    leader.shutdown()?;
    for h in handles {
        h.join().expect("worker thread panicked")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::protocol::config::ProtocolConfig;

    fn blob_data(seed: u64) -> Vec<Vec<f32>> {
        // 3 well-separated Gaussian blobs in d=16 at random centers (random
        // directions, not constant vectors — a constant vector is the one
        // case where quantizing *without* rotation is exact, which would
        // bias protocol comparisons; see Figure 1 discussion).
        let mut rng = Pcg64::new(seed);
        let mut data = Vec::new();
        for _ in 0..3 {
            let mut center = vec![0.0f32; 16];
            rng.fill_gaussian_f32(&mut center);
            crate::linalg::scale(&mut center, 3.0);
            for _ in 0..40 {
                let mut x = vec![0.0f32; 16];
                rng.fill_gaussian_f32(&mut x);
                for (v, &c) in x.iter_mut().zip(&center) {
                    *v = *v * 0.1 + c;
                }
                data.push(x);
            }
        }
        data
    }

    #[test]
    fn nearest_and_objective() {
        let centers = vec![vec![0.0f32, 0.0], vec![10.0f32, 0.0]];
        assert_eq!(nearest(&[1.0, 0.0], &centers), 0);
        assert_eq!(nearest(&[9.0, 0.0], &centers), 1);
        let data = vec![vec![1.0f32, 0.0], vec![9.0f32, 0.0]];
        assert_eq!(objective(&data, &centers), 2.0);
    }

    #[test]
    fn init_centers_distinct_for_separated_blobs() {
        let data = blob_data(3);
        let centers = init_centers(&data, 3, 5);
        assert_eq!(centers.len(), 3);
        // pairwise far apart (blobs at 0, 3, 6 per coordinate)
        for i in 0..3 {
            for j in i + 1..3 {
                assert!(
                    linalg::dist_sq(&centers[i], &centers[j]) > 1.0,
                    "centers {i},{j} too close"
                );
            }
        }
    }

    #[test]
    fn float32_matches_centralized_lloyd() {
        // With the exact protocol the distributed run must track the
        // centralized objective trajectory exactly (same init, same data).
        let data = blob_data(7);
        let proto = ProtocolConfig::parse("float32", 16).unwrap().build().unwrap();
        let cfg = KMeansConfig { n_centers: 3, n_clients: 4, iters: 5, seed: 9 };
        let result = run(&data, proto, &cfg).unwrap();

        // Centralized reference.
        let mut centers = init_centers(&data, 3, 9);
        for _ in 0..5 {
            let mut sums = vec![vec![0.0f64; 16]; 3];
            let mut counts = vec![0usize; 3];
            for x in &data {
                let c = nearest(x, &centers);
                counts[c] += 1;
                for (s, &v) in sums[c].iter_mut().zip(x) {
                    *s += v as f64;
                }
            }
            for c in 0..3 {
                if counts[c] > 0 {
                    centers[c] =
                        sums[c].iter().map(|&v| (v / counts[c] as f64) as f32).collect();
                }
            }
        }
        let want = objective(&data, &centers);
        let got = result.rounds.last().unwrap().objective;
        assert!(
            (got - want).abs() / want.max(1e-9) < 1e-3,
            "distributed {got} vs centralized {want}"
        );
    }

    #[test]
    fn quantized_kmeans_converges_on_blobs() {
        let data = blob_data(11);
        // Exact-transmission baseline: what Lloyd's itself achieves here.
        let exact = {
            let proto = ProtocolConfig::parse("float32", 16).unwrap().build().unwrap();
            let cfg = KMeansConfig { n_centers: 3, n_clients: 5, iters: 8, seed: 13 };
            run(&data, proto, &cfg).unwrap().rounds.last().unwrap().objective
        };
        for spec in ["klevel:k=64", "rotated:k=64", "varlen:k=64"] {
            let proto = ProtocolConfig::parse(spec, 16).unwrap().build().unwrap();
            let cfg = KMeansConfig { n_centers: 3, n_clients: 5, iters: 8, seed: 13 };
            let result = run(&data, proto, &cfg).unwrap();
            let final_obj = result.rounds.last().unwrap().objective;
            // Quantization noise leaves a floor above the exact-uplink
            // optimum (the per-round MSE of the center estimates); the run
            // must still collapse the objective toward it.
            assert!(
                final_obj < exact * 1.5,
                "{spec}: objective {final_obj} (exact-uplink {exact})"
            );
            assert!(result.bits_per_dim_per_iter > 0.0);
            // cum_bits strictly increasing
            for w in result.rounds.windows(2) {
                assert!(w[1].cum_bits > w[0].cum_bits);
            }
        }
    }

    #[test]
    fn handles_more_centers_than_points_per_client() {
        let data = synthetic::gaussian(8, 16, 21).rows;
        let proto = ProtocolConfig::parse("klevel:k=8", 16).unwrap().build().unwrap();
        let cfg = KMeansConfig { n_centers: 5, n_clients: 4, iters: 3, seed: 23 };
        let result = run(&data, proto, &cfg).unwrap();
        assert_eq!(result.rounds.len(), 3);
    }
}
