//! Application drivers — the paper's §7 experiments.
//!
//! * [`kmeans`] — distributed Lloyd's algorithm with quantized center
//!   uplink (Figure 2).
//! * [`power_iteration`] — distributed power iteration with quantized
//!   eigenvector uplink (Figure 3).
//!
//! Both run on the [`coordinator`](crate::coordinator) (leader + loopback
//! workers) so every experiment exercises the full stack: update function
//! → protocol encode (native or PJRT) → transport → decode → aggregate.

pub mod kmeans;
pub mod power_iteration;
