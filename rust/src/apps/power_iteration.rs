//! Distributed power iteration with quantized uplink — the paper's
//! Figure 3 experiment.
//!
//! Each round: the leader broadcasts the current eigenvector estimate `v`;
//! every client computes one local power step `(A_iᵀA_i / n_i) v` on its
//! shard, normalizes it, and uploads it through the mean-estimation
//! protocol; the leader averages the uploads, normalizes, and iterates.
//! The tracked metric is the paper's y-axis: the ℓ₂ distance between the
//! estimate and the true top eigenvector (computed centrally for
//! reference), with the sign ambiguity resolved.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::leader::spawn_local_cluster;
use crate::coordinator::worker::UpdateFn;
use crate::linalg;
use crate::protocol::Protocol;
use crate::rng::Pcg64;

/// Configuration for a distributed power-iteration run.
#[derive(Clone, Debug)]
pub struct PowerConfig {
    /// Number of clients (the paper uses 100).
    pub n_clients: usize,
    /// Power iterations.
    pub iters: usize,
    /// Seed for v₀ and protocol randomness.
    pub seed: u64,
}

impl Default for PowerConfig {
    fn default() -> Self {
        PowerConfig { n_clients: 100, iters: 10, seed: 29 }
    }
}

/// One iteration's record.
#[derive(Clone, Debug)]
pub struct PowerRound {
    pub iter: usize,
    /// ‖v − v*‖₂ against the centrally-computed ground truth (sign-fixed).
    pub eig_dist: f64,
    pub cum_bits: u64,
}

/// Full run result.
#[derive(Clone, Debug)]
pub struct PowerResult {
    pub rounds: Vec<PowerRound>,
    pub eigenvector: Vec<f32>,
    pub bits_per_dim_per_iter: f64,
}

/// Centralized power iteration — the ground-truth reference.
pub fn top_eigenvector(data: &[Vec<f32>], iters: usize, seed: u64) -> Vec<f32> {
    let d = data[0].len();
    let mut rng = Pcg64::new(crate::rng::mix(&[seed, 0x7069]));
    let mut v = vec![0.0f32; d];
    rng.fill_gaussian_f32(&mut v);
    linalg::normalize(&mut v);
    for _ in 0..iters {
        let mut next = linalg::cov_matvec(data, &v);
        if linalg::normalize(&mut next) == 0.0 {
            return v; // degenerate data
        }
        v = next;
    }
    v
}

/// Sign-invariant eigenvector distance: `min(‖a−b‖, ‖a+b‖)`.
pub fn eig_distance(a: &[f32], b: &[f32]) -> f64 {
    let plus: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 + y as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let minus: f64 = linalg::dist_sq(a, b).sqrt();
    plus.min(minus)
}

/// The power-step worker update: one local covariance matvec, normalized.
pub fn power_update() -> UpdateFn {
    Arc::new(move |broadcast: &[f32], _dim: u32, shard: &[Vec<f32>]| {
        if shard.is_empty() {
            return Vec::new();
        }
        let mut next = linalg::cov_matvec(shard, broadcast);
        // Normalize locally so every upload has comparable scale (the
        // leader re-normalizes the average; this matches the figure's
        // "each client updates the eigenvector ... and sends it back").
        linalg::normalize(&mut next);
        vec![(next, 1.0)]
    })
}

/// Run distributed power iteration over the coordinator.
pub fn run(
    data: &[Vec<f32>],
    protocol: Arc<dyn Protocol>,
    cfg: &PowerConfig,
) -> Result<PowerResult> {
    let d = protocol.dim();
    let truth = top_eigenvector(data, 100, cfg.seed);
    let shards = crate::data::Dataset::new("power", data.to_vec()).shard(cfg.n_clients);
    let (mut leader, handles) =
        spawn_local_cluster(protocol, shards, power_update(), cfg.seed);

    let mut rng = Pcg64::new(crate::rng::mix(&[cfg.seed, 0x7069]));
    let mut v = vec![0.0f32; d];
    rng.fill_gaussian_f32(&mut v);
    linalg::normalize(&mut v);

    let mut rounds = Vec::with_capacity(cfg.iters);
    let mut cum_bits = 0u64;
    for iter in 0..cfg.iters {
        let out = leader.round(iter as u64, d as u32, &v)?;
        let mut next = out.means[0].clone();
        if linalg::normalize(&mut next) > 0.0 {
            v = next;
        }
        cum_bits += out.uplink_bits;
        rounds.push(PowerRound { iter, eig_dist: eig_distance(&v, &truth), cum_bits });
    }
    leader.shutdown()?;
    for h in handles {
        h.join().expect("worker thread panicked")?;
    }
    let bits_per_dim_per_iter = cum_bits as f64 / (d as f64 * cfg.iters as f64);
    Ok(PowerResult { rounds, eigenvector: v, bits_per_dim_per_iter })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::config::ProtocolConfig;

    /// Data with a dominant direction: x = s*u + noise.
    fn spiked_data(n: usize, d: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let mut u = vec![0.0f32; d];
        rng.fill_gaussian_f32(&mut u);
        linalg::normalize(&mut u);
        let data = (0..n)
            .map(|_| {
                let s = rng.gaussian() as f32 * 3.0;
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                for (xi, &ui) in x.iter_mut().zip(&u) {
                    *xi = *xi * 0.1 + s * ui;
                }
                x
            })
            .collect();
        (data, u)
    }

    #[test]
    fn centralized_power_iteration_finds_spike() {
        let (data, u) = spiked_data(300, 32, 3);
        let v = top_eigenvector(&data, 50, 1);
        assert!(eig_distance(&v, &u) < 0.1, "dist {}", eig_distance(&v, &u));
    }

    #[test]
    fn eig_distance_sign_invariant() {
        let a = vec![1.0f32, 0.0];
        let b = vec![-1.0f32, 0.0];
        assert_eq!(eig_distance(&a, &b), 0.0);
        assert_eq!(eig_distance(&a, &a), 0.0);
    }

    #[test]
    fn float32_distributed_matches_centralized_direction() {
        let (data, _) = spiked_data(200, 16, 7);
        let proto = ProtocolConfig::parse("float32", 16).unwrap().build().unwrap();
        let cfg = PowerConfig { n_clients: 10, iters: 15, seed: 9 };
        let result = run(&data, proto, &cfg).unwrap();
        assert!(
            result.rounds.last().unwrap().eig_dist < 0.15,
            "dist {}",
            result.rounds.last().unwrap().eig_dist
        );
    }

    #[test]
    fn quantized_power_iteration_converges() {
        let (data, _) = spiked_data(200, 64, 11);
        for spec in ["rotated:k=32", "varlen:k=32", "klevel:k=32"] {
            let proto = ProtocolConfig::parse(spec, 64).unwrap().build().unwrap();
            let cfg = PowerConfig { n_clients: 20, iters: 12, seed: 13 };
            let result = run(&data, proto, &cfg).unwrap();
            let first = result.rounds.first().unwrap().eig_dist;
            let last = result.rounds.last().unwrap().eig_dist;
            // Converged: close to the true direction, and no divergence
            // from wherever the first round already got it.
            assert!(last < 0.2, "{spec}: final dist {last}");
            assert!(last < first * 1.5 + 0.05, "{spec}: dist went {first} -> {last}");
        }
    }

    #[test]
    fn bits_accounting_positive_and_monotone() {
        let (data, _) = spiked_data(50, 16, 17);
        let proto = ProtocolConfig::parse("klevel:k=4", 16).unwrap().build().unwrap();
        let cfg = PowerConfig { n_clients: 5, iters: 4, seed: 19 };
        let result = run(&data, proto, &cfg).unwrap();
        assert!(result.bits_per_dim_per_iter > 0.0);
        for w in result.rounds.windows(2) {
            assert!(w[1].cum_bits > w[0].cum_bits);
        }
    }
}
