//! Estimation-error metrics and summary statistics for experiments:
//! MSE of mean estimates, running moments, and confidence intervals over
//! repeated trials (every figure in the paper averages multiple trials).

use crate::linalg;

/// Squared ℓ₂ error of an estimate against the true mean — the paper's
/// per-trial loss `‖X̂̄ − X̄‖²`; average over trials to get the MSE
/// `E(π, Xⁿ)`.
pub fn sq_error(estimate: &[f32], truth: &[f32]) -> f64 {
    linalg::dist_sq(estimate, truth)
}

/// Exact empirical mean of client vectors (the estimand `X̄`).
pub fn true_mean(xs: &[Vec<f32>]) -> Vec<f32> {
    let refs: Vec<&[f32]> = xs.iter().map(|v| v.as_slice()).collect();
    linalg::mean_of(&refs)
}

/// Average squared norm `(1/n) Σ ‖X_i‖²` — the scale factor in all of the
/// paper's MSE bounds.
pub fn avg_norm_sq(xs: &[Vec<f32>]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|x| linalg::norm_sq(x)).sum::<f64>() / xs.len() as f64
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n−1 denominator); 0 for fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }

    /// Normal-approximation 95% confidence half-width.
    pub fn ci95(&self) -> f64 {
        1.96 * self.sem()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample (nearest-rank on a sorted copy), p in [0, 100].
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of empty sample");
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sq_error_basic() {
        assert_eq!(sq_error(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert_eq!(sq_error(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn true_mean_and_avg_norm() {
        let xs = vec![vec![0.0f32, 2.0], vec![2.0f32, 0.0]];
        assert_eq!(true_mean(&xs), vec![1.0, 1.0]);
        assert_eq!(avg_norm_sq(&xs), 4.0);
        assert_eq!(avg_norm_sq(&[]), 0.0);
    }

    #[test]
    fn running_matches_closed_form() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic dataset is 32/7
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
        assert!(r.ci95() > 0.0);
    }

    #[test]
    fn running_degenerate_cases() {
        let r = Running::new();
        assert_eq!(r.variance(), 0.0);
        assert_eq!(r.sem(), 0.0);
        let mut one = Running::new();
        one.push(3.0);
        assert_eq!(one.variance(), 0.0);
        assert_eq!(one.mean(), 3.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let s = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 50.0), 3.0);
        assert_eq!(percentile(&s, 100.0), 5.0);
    }
}
