//! Minimal property-based testing kit (no proptest in the offline crate
//! set): seeded generators + a driver that reports the failing case and the
//! seed that reproduces it.
//!
//! ```ignore
//! testkit::run_prop("roundtrip", 200, |g| {
//!     let xs = g.vec_f32(1..=64, -10.0..10.0);
//!     prop_assert(decode(encode(&xs)) == xs, format!("xs={xs:?}"));
//! });
//! ```

use crate::rng::Pcg64;
use std::ops::RangeInclusive;

/// Case generator handed to each property iteration.
pub struct Gen {
    rng: Pcg64,
    /// Human-readable trace of what was generated (printed on failure).
    pub trace: Vec<String>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Pcg64::new(seed), trace: Vec::new() }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    /// Uniform usize in an inclusive range.
    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        let v = lo + self.rng.next_below((hi - lo + 1) as u32) as usize;
        self.trace.push(format!("usize={v}"));
        v
    }

    /// Uniform u32 in an inclusive range.
    pub fn u32_in(&mut self, range: RangeInclusive<u32>) -> u32 {
        let (lo, hi) = (*range.start(), *range.end());
        let v = lo + self.rng.next_below(hi - lo + 1);
        self.trace.push(format!("u32={v}"));
        v
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + self.rng.next_f32() * (hi - lo);
        self.trace.push(format!("f32={v}"));
        v
    }

    /// A vector of f32s with random length in `len` and values in `[lo, hi)`.
    pub fn vec_f32(&mut self, len: RangeInclusive<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        let v: Vec<f32> = (0..n).map(|_| lo + self.rng.next_f32() * (hi - lo)).collect();
        self.trace.push(format!("vec_f32(len={n})"));
        v
    }

    /// A vector of u32 symbols below `bound`.
    pub fn vec_symbols(&mut self, len: RangeInclusive<usize>, bound: u32) -> Vec<u32> {
        let n = self.usize_in(len);
        let v: Vec<u32> = (0..n).map(|_| self.rng.next_below(bound)).collect();
        self.trace.push(format!("vec_symbols(len={n}, bound={bound})"));
        v
    }

    /// Power of two in `[2^lo, 2^hi]`.
    pub fn pow2(&mut self, lo: u32, hi: u32) -> usize {
        let e = self.u32_in(lo..=hi);
        1usize << e
    }
}

/// Run `cases` iterations of a property. The closure returns
/// `Err(description)` (or panics) to fail; the harness re-raises with the
/// iteration seed so the case can be replayed deterministically.
pub fn run_prop<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    // Fixed base seed: property suites are deterministic in CI; bump the
    // DME_PROP_SEED env var to explore a different region.
    let base = std::env::var("DME_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xd15e_u64 ^ 0x9e3779b97f4a7c15);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  {msg}\n  trace: {:?}",
                g.trace
            );
        }
    }
}

/// Assertion helper for use inside properties.
pub fn check(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_ranges_hold() {
        run_prop("gen_ranges", 100, |g| {
            let n = g.usize_in(3..=7);
            check((3..=7).contains(&n), format!("n={n}"))?;
            let x = g.f32_in(-1.0, 1.0);
            check((-1.0..1.0).contains(&x), format!("x={x}"))?;
            let v = g.vec_symbols(0..=10, 5);
            check(v.iter().all(|&s| s < 5), format!("v={v:?}"))?;
            let p = g.pow2(1, 4);
            check(p.is_power_of_two() && (2..=16).contains(&p), format!("p={p}"))
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn failing_property_panics_with_seed() {
        run_prop("always_fails", 5, |_g| Err("nope".into()));
    }
}
