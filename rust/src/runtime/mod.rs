//! Runtime layer: executes the AOT-compiled JAX/Pallas artifacts from the
//! Rust request path via PJRT, and defines the [`engine::ComputeBackend`]
//! abstraction that lets every protocol run its numeric hot-spots on either
//! the native Rust implementations or the compiled HLO executables.
//!
//! * [`artifacts`] — discovers `artifacts/*.hlo.txt` via `manifest.tsv`.
//! * [`engine`] — the backend trait + the pure-Rust [`engine::NativeBackend`].
//! * [`pjrt`] — the PJRT CPU client: loads HLO text, compiles once per
//!   entry point, executes on a dedicated engine thread (the `xla` crate's
//!   client is `Rc`-based and must stay on one thread; the
//!   [`pjrt::PjrtBackend`] handle is `Send + Sync` and speaks to it over a
//!   channel). Compiled only with the `pjrt` cargo feature — the default
//!   build ships a stub whose constructor returns a clean error, since the
//!   `xla` crate is not part of the offline crate set.

pub mod artifacts;
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use engine::{ComputeBackend, NativeBackend};
pub use pjrt::PjrtBackend;
