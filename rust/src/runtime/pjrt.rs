//! PJRT execution of the AOT artifacts — the bridge that puts the
//! JAX/Pallas-compiled HLO on the Rust request path.
//!
//! Artifact interchange is HLO **text** (`HloModuleProto::from_text_file`):
//! jax ≥ 0.5 serializes protos with 64-bit instruction ids which
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-based and must stay on
//! one thread. [`PjrtBackend`] is a `Send + Sync` handle that ships op
//! requests over a channel to a dedicated engine thread owning the client
//! and the compiled executables (compiled once, lazily, per entry point).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use super::artifacts::Manifest;
use crate::protocol::quantizer::{Quantized, Span};

/// Ops the engine thread serves.
enum Request {
    RotateFwd { x: Vec<f32>, sign: Vec<f32> },
    RotateInv { z: Vec<f32>, sign: Vec<f32> },
    Quantize { x: Vec<f32>, u: Vec<f32>, span: Span, k: u32 },
    EncodeRotated { x: Vec<f32>, sign: Vec<f32>, u: Vec<f32>, k: u32 },
    /// Server-side batch decode: Σ dequantize(rows) (decode_sum_d* artifact).
    DecodeSum { bins: Vec<f32>, xmin: Vec<f32>, s: Vec<f32>, k: u32, dim: usize },
    Shutdown,
}

enum Response {
    Vector(Vec<f32>),
    Quantized(Quantized),
}

struct Job {
    req: Request,
    reply: mpsc::Sender<Result<Response>>,
}

/// `Send + Sync` handle to the PJRT engine thread.
pub struct PjrtBackend {
    tx: Mutex<mpsc::Sender<Job>>,
    /// Keeps the engine thread joined on drop.
    thread: Option<std::thread::JoinHandle<()>>,
    /// Rows per decode_sum execution (compiled batch size).
    pub decode_batch: usize,
}

impl PjrtBackend {
    /// Spawn the engine thread against the default artifacts directory.
    pub fn new() -> Result<Self> {
        Self::with_dir(Manifest::default_dir())
    }

    /// Spawn the engine thread for a specific artifacts directory.
    pub fn with_dir(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("dme-pjrt-engine".into())
            .spawn(move || engine_main(manifest, rx, ready_tx))
            .context("spawning pjrt engine thread")?;
        ready_rx
            .recv()
            .context("pjrt engine thread died during init")??;
        Ok(PjrtBackend { tx: Mutex::new(tx), thread: Some(thread), decode_batch: 8 })
    }

    fn call(&self, req: Request) -> Result<Response> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .lock()
            .expect("pjrt handle poisoned")
            .send(Job { req, reply: reply_tx })
            .map_err(|_| anyhow!("pjrt engine thread is gone"))?;
        reply_rx.recv().map_err(|_| anyhow!("pjrt engine dropped reply"))?
    }

    fn call_vec(&self, req: Request) -> Result<Vec<f32>> {
        match self.call(req)? {
            Response::Vector(v) => Ok(v),
            _ => bail!("unexpected response type"),
        }
    }

    fn call_quant(&self, req: Request) -> Result<Quantized> {
        match self.call(req)? {
            Response::Quantized(q) => Ok(q),
            _ => bail!("unexpected response type"),
        }
    }

    /// Batch server-side decode: `bins` is `rows × dim` (row-major,
    /// zero-pad to the compiled batch), returns the per-dimension sums.
    pub fn decode_sum(
        &self,
        bins: Vec<f32>,
        xmin: Vec<f32>,
        s: Vec<f32>,
        k: u32,
        dim: usize,
    ) -> Result<Vec<f32>> {
        self.call_vec(Request::DecodeSum { bins, xmin, s, k, dim })
    }
}

impl Drop for PjrtBackend {
    fn drop(&mut self) {
        let (reply_tx, _reply_rx) = mpsc::channel();
        let _ = self
            .tx
            .lock()
            .map(|tx| tx.send(Job { req: Request::Shutdown, reply: reply_tx }));
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl super::engine::ComputeBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn rotate_fwd(&self, x: &[f32], sign: &[f32]) -> Result<Vec<f32>> {
        self.call_vec(Request::RotateFwd { x: x.to_vec(), sign: sign.to_vec() })
    }

    fn rotate_inv(&self, z: &[f32], sign: &[f32]) -> Result<Vec<f32>> {
        self.call_vec(Request::RotateInv { z: z.to_vec(), sign: sign.to_vec() })
    }

    fn quantize(&self, x: &[f32], u: &[f32], span: Span, k: u32) -> Result<Quantized> {
        self.call_quant(Request::Quantize { x: x.to_vec(), u: u.to_vec(), span, k })
    }

    fn encode_rotated(&self, x: &[f32], sign: &[f32], u: &[f32], k: u32) -> Result<Quantized> {
        self.call_quant(Request::EncodeRotated {
            x: x.to_vec(),
            sign: sign.to_vec(),
            u: u.to_vec(),
            k,
        })
    }
}

// ---------------------------------------------------------------------------
// Engine thread
// ---------------------------------------------------------------------------

struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Compiled executables, keyed by entry name (lazy).
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

fn engine_main(manifest: Manifest, rx: mpsc::Receiver<Job>, ready: mpsc::Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu failed: {e}")));
            return;
        }
    };
    let _ = ready.send(Ok(()));
    let mut eng = Engine { client, manifest, exes: HashMap::new() };
    while let Ok(job) = rx.recv() {
        if matches!(job.req, Request::Shutdown) {
            return;
        }
        let resp = eng.serve(job.req);
        let _ = job.reply.send(resp);
    }
}

impl Engine {
    fn exe(&mut self, op: &str, dim: usize) -> Result<&xla::PjRtLoadedExecutable> {
        let key = format!("{op}_d{dim}");
        if !self.exes.contains_key(&key) {
            let entry = self.manifest.entry_for(op, dim)?.clone();
            let proto = xla::HloModuleProto::from_text_file(
                entry.path.to_str().context("non-utf8 artifact path")?,
            )
            .map_err(|e| anyhow!("parsing {}: {e}", entry.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {key}: {e}"))?;
            self.exes.insert(key.clone(), exe);
        }
        Ok(&self.exes[&key])
    }

    fn run(&mut self, op: &str, dim: usize, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(op, dim)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {op}_d{dim}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {op}_d{dim}: {e}"))?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        lit.to_tuple().map_err(|e| anyhow!("untupling {op}_d{dim}: {e}"))
    }

    fn serve(&mut self, req: Request) -> Result<Response> {
        match req {
            Request::RotateFwd { x, sign } => {
                let d = x.len();
                let out = self.run("rotate_fwd", d, &lits(&[(&x, &[1, d]), (&sign, &[d])])?)?;
                Ok(Response::Vector(vec_of(&out[0])?))
            }
            Request::RotateInv { z, sign } => {
                let d = z.len();
                let out = self.run("rotate_inv", d, &lits(&[(&z, &[1, d]), (&sign, &[d])])?)?;
                Ok(Response::Vector(vec_of(&out[0])?))
            }
            Request::Quantize { x, u, span, k } => {
                let d = x.len();
                let op = match span {
                    Span::MinMax => "quantize_minmax",
                    Span::Norm => "quantize_norm",
                };
                let km1 = vec![(k - 1) as f32];
                let out = self.run(
                    op,
                    d,
                    &lits(&[(&x, &[1, d]), (&u, &[1, d]), (&km1, &[1, 1])])?,
                )?;
                quantized_of(&out)
            }
            Request::EncodeRotated { x, sign, u, k } => {
                let d = x.len();
                let km1 = vec![(k - 1) as f32];
                let out = self.run(
                    "encode_rotated",
                    d,
                    &lits(&[(&x, &[1, d]), (&sign, &[d]), (&u, &[1, d]), (&km1, &[1, 1])])?,
                )?;
                quantized_of(&out)
            }
            Request::DecodeSum { bins, xmin, s, k, dim } => {
                let rows = xmin.len();
                anyhow::ensure!(bins.len() == rows * dim, "bins shape mismatch");
                let km1 = vec![(k - 1) as f32];
                let out = self.run(
                    "decode_sum",
                    dim,
                    &lits(&[
                        (&bins, &[rows, dim]),
                        (&xmin, &[rows, 1]),
                        (&s, &[rows, 1]),
                        (&km1, &[1, 1]),
                    ])?,
                )?;
                Ok(Response::Vector(vec_of(&out[0])?))
            }
            Request::Shutdown => unreachable!("handled by engine_main"),
        }
    }
}

fn lits(specs: &[(&Vec<f32>, &[usize])]) -> Result<Vec<xla::Literal>> {
    specs
        .iter()
        .map(|(data, shape)| {
            let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
            xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape to {dims:?}: {e}"))
        })
        .collect()
}

fn vec_of(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))
}

fn quantized_of(out: &[xla::Literal]) -> Result<Response> {
    anyhow::ensure!(out.len() == 3, "quantize entry returns 3 outputs, got {}", out.len());
    let bins_f = vec_of(&out[0])?;
    let xmin = vec_of(&out[1])?;
    let s = vec_of(&out[2])?;
    Ok(Response::Quantized(Quantized {
        bins: bins_f.iter().map(|&b| b as u32).collect(),
        xmin: xmin[0],
        s: s[0],
    }))
}
