//! Artifact discovery: `make artifacts` (the build-time Python step) drops
//! `<entry>_d<dim>.hlo.txt` files plus a `manifest.tsv` into `artifacts/`;
//! this module locates and describes them for the PJRT loader.
//!
//! Manifest line format (written by `python/compile/aot.py`):
//! `name \t file \t dim \t num_outputs \t shape;shape;...`

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub dim: usize,
    pub num_outputs: usize,
    /// Input shapes, e.g. `[[1, 64], [64], [1, 1]]`.
    pub input_shapes: Vec<Vec<usize>>,
}

/// The set of compiled entry points available on disk.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: HashMap<String, ArtifactEntry>,
    dims: Vec<usize>,
}

impl Manifest {
    /// Load `manifest.tsv` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let tsv = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&tsv)
            .with_context(|| format!("reading {} (run `make artifacts`)", tsv.display()))?;
        let mut entries = HashMap::new();
        let mut dims = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 5 {
                bail!("manifest.tsv line {}: expected 5 fields, got {}", lineno + 1, fields.len());
            }
            let name = fields[0].to_string();
            let dim: usize = fields[2].parse().context("bad dim")?;
            let num_outputs: usize = fields[3].parse().context("bad num_outputs")?;
            let input_shapes = fields[4]
                .split(';')
                .map(|s| {
                    s.split(',')
                        .map(|x| x.parse::<usize>().context("bad shape"))
                        .collect::<Result<Vec<_>>>()
                })
                .collect::<Result<Vec<_>>>()?;
            if !dims.contains(&dim) {
                dims.push(dim);
            }
            entries.insert(
                name.clone(),
                ArtifactEntry { name, path: dir.join(fields[1]), dim, num_outputs, input_shapes },
            );
        }
        dims.sort_unstable();
        Ok(Manifest { entries, dims })
    }

    /// Default artifacts directory: `$DME_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("DME_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.get(name)
    }

    /// Entry `<op>_d<dim>`, e.g. `rotate_fwd_d256`.
    pub fn entry_for(&self, op: &str, dim: usize) -> Result<&ArtifactEntry> {
        let key = format!("{op}_d{dim}");
        self.entries.get(&key).with_context(|| {
            format!(
                "no artifact `{key}` (compiled dims: {:?}; re-run `make artifacts` \
                 or add the dim to python/compile/aot.py DIMS)",
                self.dims
            )
        })
    }

    /// Dimensions with compiled artifacts.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, lines: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.tsv")).unwrap();
        f.write_all(lines.as_bytes()).unwrap();
    }

    #[test]
    fn parses_wellformed_manifest() {
        let dir = std::env::temp_dir().join(format!("dme_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(
            &dir,
            "rotate_fwd_d16\trotate_fwd_d16.hlo.txt\t16\t1\t1,16;16\n\
             decode_sum_d16\tdecode_sum_d16.hlo.txt\t16\t1\t8,16;8,1;8,1;1,1\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m.dims(), &[16]);
        let e = m.entry_for("rotate_fwd", 16).unwrap();
        assert_eq!(e.num_outputs, 1);
        assert_eq!(e.input_shapes, vec![vec![1, 16], vec![16]]);
        assert!(m.entry_for("rotate_fwd", 32).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn malformed_line_rejected() {
        let dir = std::env::temp_dir().join(format!("dme_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, "only\ttwo\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // Exercised in CI after `make artifacts`; skipped silently otherwise.
        let dir = Manifest::default_dir();
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(!m.is_empty());
            for dim in [16usize, 64, 256, 512, 1024] {
                assert!(m.entry_for("encode_rotated", dim).is_ok(), "missing dim {dim}");
            }
        }
    }
}
