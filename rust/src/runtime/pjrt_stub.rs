//! Stub PJRT backend, compiled when the `pjrt` cargo feature is off.
//!
//! The real backend (`pjrt.rs`) depends on the vendored `xla` crate,
//! which is not part of the default offline crate set. This stub keeps
//! the public surface identical so `--backend pjrt` call sites compile
//! unconditionally: every constructor returns a clean error and callers
//! fall back to the native backend (or skip, as the integration tests do
//! when no artifacts are present).

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::protocol::quantizer::{Quantized, Span};

const UNAVAILABLE: &str = "dme was built without the `pjrt` feature; rebuild with \
     `--features pjrt` (and the vendored `xla` crate) to execute AOT artifacts";

/// Stand-in for the PJRT engine handle. Never constructible: both
/// constructors return the "built without pjrt" error.
pub struct PjrtBackend {
    /// Rows per decode_sum execution (mirrors the real backend's field).
    pub decode_batch: usize,
}

impl PjrtBackend {
    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn new() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    /// Always fails: the crate was built without the `pjrt` feature.
    pub fn with_dir(_dir: PathBuf) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    /// Unreachable in practice (no instance can exist); kept for API parity.
    pub fn decode_sum(
        &self,
        _bins: Vec<f32>,
        _xmin: Vec<f32>,
        _s: Vec<f32>,
        _k: u32,
        _dim: usize,
    ) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }
}

impl super::engine::ComputeBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt (stubbed out)"
    }

    fn rotate_fwd(&self, _x: &[f32], _sign: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    fn rotate_inv(&self, _z: &[f32], _sign: &[f32]) -> Result<Vec<f32>> {
        bail!(UNAVAILABLE)
    }

    fn quantize(&self, _x: &[f32], _u: &[f32], _span: Span, _k: u32) -> Result<Quantized> {
        bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = PjrtBackend::new().unwrap_err();
        assert!(err.to_string().contains("pjrt"), "unhelpful error: {err}");
    }
}
