//! The compute-backend abstraction: every protocol's numeric hot-spots
//! (rotation, stochastic quantization) go through [`ComputeBackend`], so
//! the same protocol code runs on the native Rust implementations or on
//! the AOT-compiled JAX/Pallas executables ([`super::pjrt::PjrtBackend`]).
//!
//! Randomness is always produced by the *caller* (uniforms and Rademacher
//! signs are arguments), so both backends are deterministic given the same
//! streams and can be cross-validated bin-for-bin.

use std::sync::Arc;

use anyhow::Result;

use crate::protocol::quantizer::{self, Quantized, Span};
use crate::rotation::hadamard;

/// Numeric operations a protocol may offload.
pub trait ComputeBackend: Send + Sync {
    /// Backend label for logs/metrics.
    fn name(&self) -> &'static str;

    /// `z = (1/√d) H (sign ⊙ x)` — the paper's rotation `R = HD`.
    /// `x.len()` must equal `sign.len()` and be a power of two.
    fn rotate_fwd(&self, x: &[f32], sign: &[f32]) -> Result<Vec<f32>>;

    /// `x = sign ⊙ (1/√d) H z` — the inverse rotation `R⁻¹`.
    fn rotate_inv(&self, z: &[f32], sign: &[f32]) -> Result<Vec<f32>>;

    /// Stochastic k-level quantization of `x` with uniforms `u` (§2.2).
    fn quantize(&self, x: &[f32], u: &[f32], span: Span, k: u32) -> Result<Quantized>;

    /// Fused client step of π_srk: rotate then quantize (minmax span).
    /// The default composes the two ops; the PJRT backend uses the fused
    /// `encode_rotated_d*` executable instead.
    fn encode_rotated(&self, x: &[f32], sign: &[f32], u: &[f32], k: u32) -> Result<Quantized> {
        let z = self.rotate_fwd(x, sign)?;
        self.quantize(&z, u, Span::MinMax, k)
    }

    /// Stochastic quantization into caller storage — the round-session
    /// encode path. The native backend overrides this allocation-free;
    /// the default routes through [`Self::quantize`] and copies. Returns
    /// the grid `(xmin, s)`.
    fn quantize_into(
        &self,
        x: &[f32],
        u: &[f32],
        span: Span,
        k: u32,
        bins: &mut Vec<u32>,
    ) -> Result<(f32, f32)> {
        let q = self.quantize(x, u, span, k)?;
        bins.clear();
        bins.extend_from_slice(&q.bins);
        Ok((q.xmin, q.s))
    }

    /// Fused in-place client step of π_srk for the round-session encode
    /// path: rotate `buf` (already padded to a power of two) in place,
    /// then quantize into `bins` (minmax span). `buf`'s contents are
    /// unspecified afterwards. The native backend overrides this
    /// allocation-free; the default routes through
    /// [`Self::encode_rotated`] and copies.
    fn encode_rotated_in_place(
        &self,
        buf: &mut [f32],
        sign: &[f32],
        u: &[f32],
        k: u32,
        bins: &mut Vec<u32>,
    ) -> Result<(f32, f32)> {
        let q = self.encode_rotated(buf, sign, u, k)?;
        bins.clear();
        bins.extend_from_slice(&q.bins);
        Ok((q.xmin, q.s))
    }
}

/// Pure-Rust backend (always available, any dimension).
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    /// Shared singleton — protocols default to this.
    pub fn shared() -> Arc<dyn ComputeBackend> {
        static ONCE: std::sync::OnceLock<Arc<NativeBackend>> = std::sync::OnceLock::new();
        ONCE.get_or_init(|| Arc::new(NativeBackend)).clone() as Arc<dyn ComputeBackend>
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn rotate_fwd(&self, x: &[f32], sign: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == sign.len(), "dim mismatch");
        let mut z: Vec<f32> = x.iter().zip(sign).map(|(a, s)| a * s).collect();
        hadamard::fwht_normalized(&mut z);
        Ok(z)
    }

    fn rotate_inv(&self, z: &[f32], sign: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(z.len() == sign.len(), "dim mismatch");
        let mut x = z.to_vec();
        hadamard::fwht_normalized(&mut x);
        for (v, s) in x.iter_mut().zip(sign) {
            *v *= s;
        }
        Ok(x)
    }

    fn quantize(&self, x: &[f32], u: &[f32], span: Span, k: u32) -> Result<Quantized> {
        anyhow::ensure!(x.len() == u.len(), "uniforms length mismatch");
        anyhow::ensure!(k >= 2, "k must be >= 2");
        Ok(quantizer::quantize(x, u, span, k))
    }

    fn quantize_into(
        &self,
        x: &[f32],
        u: &[f32],
        span: Span,
        k: u32,
        bins: &mut Vec<u32>,
    ) -> Result<(f32, f32)> {
        anyhow::ensure!(x.len() == u.len(), "uniforms length mismatch");
        anyhow::ensure!(k >= 2, "k must be >= 2");
        let (xmin, s) = quantizer::grid_params(x, span);
        quantizer::quantize_into(x, u, xmin, s, k, bins);
        Ok((xmin, s))
    }

    fn encode_rotated_in_place(
        &self,
        buf: &mut [f32],
        sign: &[f32],
        u: &[f32],
        k: u32,
        bins: &mut Vec<u32>,
    ) -> Result<(f32, f32)> {
        anyhow::ensure!(buf.len() == sign.len(), "dim mismatch");
        for (v, s) in buf.iter_mut().zip(sign) {
            *v *= s;
        }
        hadamard::fwht_normalized(buf);
        self.quantize_into(buf, u, Span::MinMax, k, bins)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn native_rotate_roundtrip() {
        let b = NativeBackend;
        let mut rng = Pcg64::new(1);
        let mut x = vec![0.0f32; 64];
        rng.fill_gaussian_f32(&mut x);
        let mut sign = vec![0.0f32; 64];
        rng.fill_rademacher(&mut sign);
        let z = b.rotate_fwd(&x, &sign).unwrap();
        let back = b.rotate_inv(&z, &sign).unwrap();
        for (a, b) in back.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn native_encode_rotated_matches_composition() {
        let b = NativeBackend;
        let mut rng = Pcg64::new(2);
        let mut x = vec![0.0f32; 32];
        rng.fill_gaussian_f32(&mut x);
        let mut sign = vec![0.0f32; 32];
        rng.fill_rademacher(&mut sign);
        let mut u = vec![0.0f32; 32];
        rng.fill_uniform_f32(&mut u);
        let fused = b.encode_rotated(&x, &sign, &u, 16).unwrap();
        let z = b.rotate_fwd(&x, &sign).unwrap();
        let composed = b.quantize(&z, &u, Span::MinMax, 16).unwrap();
        assert_eq!(fused.bins, composed.bins);
        assert_eq!(fused.xmin, composed.xmin);
        assert_eq!(fused.s, composed.s);
    }

    #[test]
    fn in_place_fused_matches_allocating() {
        let b = NativeBackend;
        let mut rng = Pcg64::new(7);
        let mut x = vec![0.0f32; 64];
        rng.fill_gaussian_f32(&mut x);
        let mut sign = vec![0.0f32; 64];
        rng.fill_rademacher(&mut sign);
        let mut u = vec![0.0f32; 64];
        rng.fill_uniform_f32(&mut u);
        let q = b.encode_rotated(&x, &sign, &u, 16).unwrap();
        let mut buf = x.clone();
        let mut bins = Vec::new();
        let (xmin, s) = b.encode_rotated_in_place(&mut buf, &sign, &u, 16, &mut bins).unwrap();
        assert_eq!(bins, q.bins);
        assert_eq!(xmin, q.xmin);
        assert_eq!(s, q.s);
        // quantize_into agrees with quantize as well
        let qq = b.quantize(&x, &u, Span::Norm, 8).unwrap();
        let (xmin2, s2) = b.quantize_into(&x, &u, Span::Norm, 8, &mut bins).unwrap();
        assert_eq!(bins, qq.bins);
        assert_eq!((xmin2, s2), (qq.xmin, qq.s));
    }

    #[test]
    fn dim_mismatch_rejected() {
        let b = NativeBackend;
        assert!(b.rotate_fwd(&[1.0; 4], &[1.0; 8]).is_err());
        assert!(b.quantize(&[1.0; 4], &[0.5; 3], Span::MinMax, 4).is_err());
        assert!(b.quantize(&[1.0; 4], &[0.5; 4], Span::MinMax, 1).is_err());
    }

    #[test]
    fn shared_singleton_is_native() {
        assert_eq!(NativeBackend::shared().name(), "native");
    }
}
