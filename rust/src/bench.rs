//! A small benchmarking harness (criterion is not in the offline crate
//! set): warmup + timed iterations with mean/p50/p99 and throughput, plus
//! the table printer every figure-bench uses for its output rows.

use std::time::{Duration, Instant};

use crate::stats;

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    /// Optional work units per iteration (bytes, elements…) for throughput.
    pub units_per_iter: Option<f64>,
}

impl Timing {
    /// Units per second, if units were declared.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / self.mean.as_secs_f64())
    }

    pub fn row(&self) -> String {
        let tput = match self.throughput() {
            Some(t) if t >= 1e9 => format!("{:8.2} G/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("{:8.2} M/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("{:8.2} k/s", t / 1e3),
            Some(t) => format!("{t:8.2}  /s"),
            None => "         --".into(),
        };
        format!(
            "{:<44} {:>10} {:>10} {:>10} {}",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.p50),
            fmt_dur(self.p99),
            tput
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Benchmark runner: measures each case with warmup, auto-scaling the
/// iteration count to the time budget.
pub struct Bench {
    /// Target measurement time per case.
    pub budget: Duration,
    /// Warmup time per case.
    pub warmup: Duration,
    timings: Vec<Timing>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // DME_BENCH_BUDGET_MS lets CI shrink runs.
        let ms = std::env::var("DME_BENCH_BUDGET_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(500u64);
        Bench {
            budget: Duration::from_millis(ms),
            warmup: Duration::from_millis((ms / 5).max(1)),
            timings: Vec::new(),
        }
    }

    /// Time `f`, labeling the case; `units_per_iter` enables throughput.
    pub fn run(&mut self, name: &str, units_per_iter: Option<f64>, mut f: impl FnMut()) -> &Timing {
        // Warmup and calibration.
        let t0 = Instant::now();
        let mut calib_iters = 0usize;
        while t0.elapsed() < self.warmup || calib_iters == 0 {
            f();
            calib_iters += 1;
        }
        let per_iter = t0.elapsed() / calib_iters as u32;
        let iters = (self.budget.as_secs_f64() / per_iter.as_secs_f64().max(1e-9))
            .ceil()
            .clamp(5.0, 1e7) as usize;

        let mut samples = Vec::with_capacity(iters.min(10_000));
        // Group iterations so per-sample clock overhead stays < ~1%.
        let group = (iters / 1000).max(1);
        let mut done = 0usize;
        while done < iters {
            let g0 = Instant::now();
            for _ in 0..group {
                f();
            }
            let dt = g0.elapsed() / group as u32;
            samples.push(dt.as_secs_f64());
            done += group;
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let timing = Timing {
            name: name.to_string(),
            iters,
            mean: Duration::from_secs_f64(mean),
            p50: Duration::from_secs_f64(stats::percentile(&samples, 50.0)),
            p99: Duration::from_secs_f64(stats::percentile(&samples, 99.0)),
            units_per_iter,
        };
        self.timings.push(timing);
        self.timings.last().unwrap()
    }

    /// Record a single externally-timed measurement as one row. For
    /// cases the harness cannot re-run at will (a 64k-connection accept
    /// storm, a one-shot scale round): mean == p50 == p99 == `elapsed`,
    /// iters == 1, and `units_per_iter` still enables throughput.
    pub fn record(
        &mut self,
        name: &str,
        units_per_iter: Option<f64>,
        elapsed: Duration,
    ) -> &Timing {
        self.timings.push(Timing {
            name: name.to_string(),
            iters: 1,
            mean: elapsed,
            p50: elapsed,
            p99: elapsed,
            units_per_iter,
        });
        self.timings.last().unwrap()
    }

    /// Print all rows with a header.
    pub fn report(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>10} {:>10} {:>10} {:>12}",
            "case", "mean", "p50", "p99", "throughput"
        );
        for t in &self.timings {
            println!("{}", t.row());
        }
    }

    pub fn timings(&self) -> &[Timing] {
        &self.timings
    }

    /// Machine-readable results: a JSON array of case objects (the CI
    /// artifact that tracks the perf trajectory across commits). Names
    /// are plain ASCII; escape the few JSON-special characters anyway.
    pub fn to_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, t) in self.timings.iter().enumerate() {
            let esc: String = t
                .name
                .chars()
                .flat_map(|c| match c {
                    '"' => vec!['\\', '"'],
                    '\\' => vec!['\\', '\\'],
                    c if (c as u32) < 0x20 => vec![' '],
                    c => vec![c],
                })
                .collect();
            let tput = match t.throughput() {
                // A sub-resolution mean yields inf — not a JSON token.
                Some(v) if v.is_finite() => format!("{v}"),
                _ => "null".to_string(),
            };
            out.push_str(&format!(
                "  {{\"name\": \"{esc}\", \"iters\": {}, \"mean_ns\": {}, \"p50_ns\": {}, \
                 \"p99_ns\": {}, \"units_per_sec\": {tput}}}{}\n",
                t.iters,
                t.mean.as_nanos(),
                t.p50.as_nanos(),
                t.p99.as_nanos(),
                if i + 1 < self.timings.len() { "," } else { "" },
            ));
        }
        out.push_str("]\n");
        out
    }

    /// Write [`Self::to_json`] to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Print a generic results table (the figure benches' row format).
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let widths: Vec<usize> = header
        .iter()
        .enumerate()
        .map(|(i, h)| {
            rows.iter()
                .map(|r| r.get(i).map(|c| c.len()).unwrap_or(0))
                .chain(std::iter::once(h.len()))
                .max()
                .unwrap_or(0)
        })
        .collect();
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&head));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len().saturating_sub(1))));
    for r in rows {
        println!("{}", fmt_row(r));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::new();
        b.budget = Duration::from_millis(20);
        b.warmup = Duration::from_millis(4);
        let mut x = 0u64;
        let t = b.run("spin", Some(1000.0), || {
            // black_box keeps the loop alive under -O3
            for i in 0..1000 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
        });
        assert!(t.mean.as_secs_f64() > 0.0);
        assert!(t.throughput().unwrap() > 0.0);
        assert!(t.row().contains("spin"));
        std::hint::black_box(x);
        b.report("test");
        let json = b.to_json();
        assert!(json.starts_with('['), "{json}");
        assert!(json.contains("\"name\": \"spin\""), "{json}");
        assert!(json.contains("\"units_per_sec\""), "{json}");
        assert!(json.trim_end().ends_with(']'), "{json}");
    }

    #[test]
    fn record_adds_a_one_shot_row() {
        let mut b = Bench::new();
        let t = b.record("one-shot", Some(10.0), Duration::from_millis(2));
        assert_eq!(t.iters, 1);
        assert_eq!(t.mean, Duration::from_millis(2));
        assert_eq!(t.p99, Duration::from_millis(2));
        assert!(b.to_json().contains("\"one-shot\""));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50 ms");
    }

    #[test]
    fn table_printer_does_not_panic() {
        print_table(
            "t",
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }
}
