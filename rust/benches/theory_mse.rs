//! Theory check (Lemmas 2–4, Theorems 2–3): measured MSE versus the
//! paper's analytic forms across a (d, n, k) sweep.
//!
//! Reported per row: measured MSE, the analytic bound, and their ratio.
//! Every ratio must be ≤ 1 (bounds hold); π_sb is additionally compared
//! against the *exact* Lemma 2 expression, and the Lemma 4 worst case is
//! exercised to show the binary bound is tight (ratio ≈ 1 − 2/d).
//!
//! ```bash
//! cargo bench --offline --bench theory_mse
//! ```

use dme::bench::print_table;
use dme::data::synthetic;
use dme::linalg;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::{run_round, RoundCtx};
use dme::report::Report;
use dme::stats;

fn measure(proto: &dyn dme::Protocol, xs: &[Vec<f32>], trials: u64) -> f64 {
    let truth = stats::true_mean(xs);
    let mut err = stats::Running::new();
    for t in 0..trials {
        let ctx = RoundCtx::new(t, 77);
        let (est, _) = run_round(proto, &ctx, xs).unwrap();
        err.push(stats::sq_error(&est, &truth));
    }
    err.mean()
}

fn main() -> anyhow::Result<()> {
    let trials: u64 = std::env::var("DME_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(60);
    let mut report =
        Report::new("theory_mse", &["protocol", "d", "n", "k", "mse", "bound", "ratio"]);
    let mut rows = Vec::new();

    for (d, n) in [(64usize, 4usize), (256, 16), (1024, 16)] {
        let data = synthetic::gaussian(n, d, d as u64 + n as u64);
        let avg = stats::avg_norm_sq(&data.rows);
        for spec in [
            "binary".to_string(),
            "klevel:k=4".into(),
            "klevel:k=16".into(),
            "rotated:k=4".into(),
            "rotated:k=16".into(),
            "varlen:k=16".into(),
        ] {
            let proto = ProtocolConfig::parse(&spec, d)?.build()?;
            let mse = measure(proto.as_ref(), &data.rows, trials);
            let bound = proto.mse_bound(n, avg).unwrap();
            let ratio = mse / bound;
            report.push(vec![
                proto.name().into(),
                d.into(),
                n.into(),
                spec.split("k=").nth(1).and_then(|s| s.parse::<u64>().ok()).unwrap_or(2).into(),
                mse.into(),
                bound.into(),
                ratio.into(),
            ]);
            rows.push(vec![
                proto.name(),
                format!("{d}"),
                format!("{n}"),
                format!("{mse:.3e}"),
                format!("{bound:.3e}"),
                format!("{ratio:.3}"),
            ]);
            assert!(ratio <= 1.05, "{spec} d={d} n={n}: bound violated ({ratio:.3})");
        }
    }

    // Lemma 4 worst case: binary MSE >= (d-2)/(2n) avg -- bound is tight.
    {
        let (d, n) = (128usize, 4usize);
        let mut x = vec![0.0f32; d];
        x[0] = 1.0 / 2.0f32.sqrt();
        x[1] = -1.0 / 2.0f32.sqrt();
        let xs = vec![x; n];
        let proto = ProtocolConfig::parse("binary", d)?.build()?;
        let mse = measure(proto.as_ref(), &xs, trials);
        let avg = stats::avg_norm_sq(&xs);
        let lower = (d as f64 - 2.0) / (2.0 * n as f64) * avg;
        let upper = d as f64 / (2.0 * n as f64) * avg;
        rows.push(vec![
            "binary (Lemma 4 worst case)".into(),
            format!("{d}"),
            format!("{n}"),
            format!("{mse:.3e}"),
            format!("[{lower:.3e}, {upper:.3e}]"),
            format!("{:.3}", mse / upper),
        ]);
        assert!(mse >= lower * 0.9 && mse <= upper * 1.1, "Lemma 4 tightness failed");
    }

    // Exact Lemma 2 check on one configuration.
    {
        let (d, n) = (64usize, 8usize);
        let data = synthetic::gaussian(n, d, 3);
        let exact: f64 = data
            .rows
            .iter()
            .map(|x| {
                let (lo, hi) = linalg::min_max(x);
                x.iter().map(|&v| (hi as f64 - v as f64) * (v as f64 - lo as f64)).sum::<f64>()
            })
            .sum::<f64>()
            / (n * n) as f64;
        let proto = ProtocolConfig::parse("binary", d)?.build()?;
        let mse = measure(proto.as_ref(), &data.rows, trials * 4);
        rows.push(vec![
            "binary vs exact Lemma 2".into(),
            format!("{d}"),
            format!("{n}"),
            format!("{mse:.3e}"),
            format!("{exact:.3e}"),
            format!("{:.3}", mse / exact),
        ]);
        assert!((mse / exact - 1.0).abs() < 0.15, "Lemma 2 exactness failed: {}", mse / exact);
    }

    print_table(
        "Theory: measured MSE vs analytic bounds (all ratios must be <= 1)",
        &["protocol", "d", "n", "measured", "bound", "ratio"],
        &rows,
    );
    report.write(dme::report::default_dir())?;
    println!("\nAll bounds hold. Series in reports/theory_mse.{{csv,json}}");
    Ok(())
}
