//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. Arithmetic vs Huffman coding in π_svk (bits & MSE identical bins).
//! 2. Span rule s_i = X^max−X^min vs √2‖X‖ in π_sk / π_svk.
//! 3. Rotation + variable-length combined — §6 argues it cannot help
//!    (rotation flattens the histogram, killing the entropy gain);
//!    we measure it.
//! 4. Histogram header mode: enumerative vs Elias-δ cost on real frames.
//! 5. Native vs PJRT backend: identical statistics (and the perf gap).
//!
//! ```bash
//! cargo bench --offline --bench ablations
//! ```

use dme::bench::print_table;
use dme::coding::{histogram, histogram_entropy_bits};
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::quantizer::Span;
use dme::protocol::varlen::{Coder, VarlenProtocol};
use dme::protocol::{run_round, Protocol, RoundCtx};
use dme::report::Report;
use dme::stats;

fn measure(proto: &dyn Protocol, xs: &[Vec<f32>], trials: u64) -> (f64, f64) {
    let truth = stats::true_mean(xs);
    let mut err = stats::Running::new();
    let mut bits = stats::Running::new();
    for t in 0..trials {
        let ctx = RoundCtx::new(t, 3);
        let (est, b) = run_round(proto, &ctx, xs).unwrap();
        err.push(stats::sq_error(&est, &truth));
        bits.push(b as f64 / xs.len() as f64);
    }
    (err.mean(), bits.mean())
}

fn main() -> anyhow::Result<()> {
    let trials: u64 = std::env::var("DME_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(15);
    let d = 256;
    let n = 64;
    let data = synthetic::gaussian(n, d, 7);
    let mut report = Report::new("ablations", &["ablation", "variant", "mse", "bits_per_client"]);
    let mut push = |report: &mut Report, ab: &str, variant: String, mse: f64, bits: f64| {
        report.push(vec![ab.into(), variant.clone().into(), mse.into(), bits.into()]);
        vec![ab.to_string(), variant, format!("{mse:.3e}"), format!("{bits:.1}")]
    };
    let mut rows = Vec::new();

    // 1. coder
    for coder in [Coder::Arithmetic, Coder::Huffman] {
        let p = VarlenProtocol::new(d, 17).with_coder(coder);
        let (mse, bits) = measure(&p, &data.rows, trials);
        rows.push(push(&mut report, "coder", p.name(), mse, bits));
    }

    // 2. span rule
    for span in [Span::MinMax, Span::Norm] {
        let p = VarlenProtocol::new(d, 17).with_span(span);
        let (mse, bits) = measure(&p, &data.rows, trials);
        rows.push(push(&mut report, "span", format!("varlen {span:?}"), mse, bits));
    }

    // 3. rotation + varlen combined (the §6 "cannot help" claim): compare
    //    varlen bits on raw vs rotated vectors via bin entropy.
    {
        let raw = VarlenProtocol::new(d, 17);
        let (mse_raw, bits_raw) = measure(&raw, &data.rows, trials);
        rows.push(push(&mut report, "rot+varlen", "varlen on raw".into(), mse_raw, bits_raw));
        // Pre-rotate the data, then varlen (what combining would do).
        let rot = dme::rotation::Rotation::sample(d, &mut dme::rng::public_stream(5, 0));
        let rotated: Vec<Vec<f32>> = data.rows.iter().map(|x| rot.forward(x)).collect();
        let (mse_rot, bits_rot) = measure(&raw, &rotated, trials);
        rows.push(push(&mut report, "rot+varlen", "varlen on rotated".into(), mse_rot, bits_rot));
        println!(
            "Sec.6 check: varlen on rotated data costs {:.1} bits vs {:.1} raw — no gain",
            bits_rot, bits_raw
        );
    }

    // 4. histogram header modes on a representative frame
    {
        let k = 17u32;
        let x = &data.rows[0];
        let mut u = vec![0.0f32; d];
        dme::rng::private_stream(1, 0, 0).fill_uniform_f32(&mut u);
        let q = dme::protocol::quantizer::quantize(x, &u, Span::Norm, k);
        let mut hist = vec![0u64; k as usize];
        for &b in &q.bins {
            hist[b as usize] += 1;
        }
        let mut w = dme::coding::BitWriter::new();
        let hdr_bits = histogram::encode(&mut w, &hist, d as u64)?;
        let enum_bits = histogram::enumerative_bits(d as u64, k as u64);
        let entropy = histogram_entropy_bits(&hist) * d as f64;
        rows.push(vec![
            "hist header".into(),
            "picked mode".into(),
            format!("{hdr_bits} bits"),
            format!("enum={enum_bits}"),
        ]);
        println!(
            "histogram header: picked {hdr_bits} bits \
             (enumerative {enum_bits}, payload entropy {entropy:.0})"
        );
    }

    // 5b. cross-paper comparator: QSGD-style Elias coding (ref [2]) vs
    //     pi_svk at matched k.
    for spec in ["qsgd:k=17", "varlen:k=17", "klevel:k=17"] {
        let proto = ProtocolConfig::parse(spec, d)?.build()?;
        let (mse, bits) = measure(proto.as_ref(), &data.rows, trials);
        rows.push(push(&mut report, "vs QSGD", proto.name(), mse, bits));
    }

    // 5c. coordinate sampling (§5 remark): varlen inner, sweep q.
    for q in [1.0f64, 0.5, 0.25] {
        let proto = ProtocolConfig::parse(&format!("varlen:k=17,q={q}"), d)?.build()?;
        let (mse, bits) = measure(proto.as_ref(), &data.rows, trials);
        rows.push(push(&mut report, "coord q", proto.name(), mse, bits));
    }

    // 5. native vs PJRT backend (statistics must match; timing in micro).
    if dme::runtime::artifacts::Manifest::default_dir().join("manifest.tsv").exists() {
        if let Ok(pjrt) = dme::runtime::PjrtBackend::new() {
            let pjrt =
                std::sync::Arc::new(pjrt) as std::sync::Arc<dyn dme::runtime::ComputeBackend>;
            for (label, cfg) in [
                ("native", ProtocolConfig::parse("rotated:k=16", d)?),
                ("pjrt", ProtocolConfig::parse("rotated:k=16", d)?.with_backend(pjrt)),
            ] {
                let proto = cfg.build()?;
                let (mse, bits) = measure(proto.as_ref(), &data.rows, trials.min(5));
                rows.push(push(&mut report, "backend", format!("rotated {label}"), mse, bits));
            }
        }
    } else {
        println!("(skipping backend ablation: run `make artifacts`)");
    }

    print_table(
        "Ablations",
        &["ablation", "variant", "MSE", "bits/client"],
        &rows,
    );
    report.write(dme::report::default_dir())?;
    println!("\nseries in reports/ablations.{{csv,json}}");
    Ok(())
}
