//! Soak: a depth-2 TCP aggregation tree under the reactor transport,
//! full multi-tenant protocol traffic, hard wall-clock budget.
//!
//! Shape: a `SessionMux` over one root reactor hub hosts one leader per
//! tenant; the shared tree fans in 16 aggregators (each running every
//! session); each aggregator (its own reactor hub) serves its span of
//! simulated clients, driven by one [`Swarm`] thread per aggregator
//! running real `Worker::step_for` encodes per session (spec `binary`,
//! d = 512). At the default n = 2048 that is 2048 live sockets and ~34
//! threads (16 aggregators + 16 swarm drivers + 17 reactors), never a
//! thread per client — and never a socket per tenant: the envelope's
//! session id multiplexes every tenant over the same connections.
//!
//! Knobs (env): `DME_SOAK_N` (default 2048), `DME_SOAK_TENANTS` (2),
//! `DME_SOAK_ROUNDS` (5), `DME_SOAK_BUDGET_MS` (60000 — the run
//! **asserts** it finishes under this). `--json out.json` writes round
//! latencies and per-session byte splits for the CI artifact.

#[cfg(not(target_os = "linux"))]
fn main() {
    eprintln!("soak bench requires linux (epoll reactor transport)");
}

#[cfg(target_os = "linux")]
fn main() -> anyhow::Result<()> {
    use std::sync::mpsc;
    use std::sync::Arc;
    use std::time::Instant;

    use dme::coordinator::aggregator::Aggregator;
    use dme::coordinator::leader::Leader;
    use dme::coordinator::reactor::raise_nofile_limit;
    use dme::coordinator::session::SessionMux;
    use dme::coordinator::swarm::Swarm;
    use dme::coordinator::topology::Topology;
    use dme::coordinator::transport::{
        DEFAULT_CONNECT_RETRIES, Envelope, HubBinding, Message, TcpEndpoint, Transport,
    };
    use dme::coordinator::worker::{mean_update, Worker};
    use dme::protocol::config::ProtocolConfig;
    use dme::protocol::{EncodeScratch, Protocol};
    use dme::rng::Pcg64;

    let env_num = |key: &str, default: u64| -> u64 {
        std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let n = env_num("DME_SOAK_N", 2048) as usize;
    let n_tenants = env_num("DME_SOAK_TENANTS", 2).clamp(1, u16::MAX as u64) as usize;
    let rounds = env_num("DME_SOAK_ROUNDS", 5);
    let budget_ms = env_num("DME_SOAK_BUDGET_MS", 60_000);
    let d = 512usize;
    let spec = "binary";
    let seed = 41u64;
    let n_aggs = 16usize;
    let fan_in = n.div_ceil(n_aggs).max(1);
    // Tenant sessions start at 1: session 0 is the root/solo wire id.
    let sessions: Vec<u16> = (1..=n_tenants as u16).collect();

    raise_nofile_limit();
    let topo = Topology::uniform(n as u64, fan_in, 2)?;
    let tier = &topo.levels()[0];
    println!(
        "soak: n={n} clients x {n_tenants} tenants, {} aggregators (fan-in {fan_in}), d={d} \
         {spec}, {rounds} rounds, budget {budget_ms} ms",
        tier.len()
    );

    let t_start = Instant::now();
    let leader_binding = HubBinding::bind(Transport::Reactor, "127.0.0.1:0")?;
    let leader_addr = leader_binding.local_addr()?.to_string();

    // Aggregators: bind a reactor hub for their span, report its
    // address, accept their children, connect upstream with backoff.
    let (addr_tx, addr_rx) = mpsc::channel::<(usize, String)>();
    let mut agg_threads = Vec::new();
    for (idx, node) in tier.iter().enumerate() {
        let leader_addr = leader_addr.clone();
        let addr_tx = addr_tx.clone();
        let sessions = sessions.clone();
        let (span, id, n_children) = (node.span, node.id, node.children.len());
        agg_threads.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let tenants: Vec<(u16, Arc<dyn Protocol>)> = sessions
                .iter()
                .map(|&s| Ok((s, ProtocolConfig::parse(spec, d)?.build()?)))
                .collect::<anyhow::Result<_>>()?;
            let proto = tenants[0].1.clone();
            let binding = HubBinding::bind(Transport::Reactor, "127.0.0.1:0")?;
            addr_tx.send((idx, binding.local_addr()?.to_string())).ok();
            let hub = binding.accept(n_children)?;
            let mut up = TcpEndpoint::connect_with_backoff(&leader_addr, DEFAULT_CONNECT_RETRIES)?;
            Aggregator::new(proto, seed, id, span)
                .with_level(0)
                .with_session_protocols(&tenants)
                .run(hub, &mut up)?;
            Ok(())
        }));
    }
    drop(addr_tx);
    let mut agg_addrs = vec![String::new(); tier.len()];
    for _ in 0..tier.len() {
        let (idx, addr) = addr_rx.recv()?;
        agg_addrs[idx] = addr;
    }

    // One swarm per aggregator: its span's clients on one driver thread,
    // each replying to every session's RoundStart with a real
    // protocol-encoded upload keyed to that session (the session id
    // feeds the private-stream derivation), and hanging up only after
    // every tenant's Shutdown.
    let mut swarms = Vec::new();
    for (idx, node) in tier.iter().enumerate() {
        let span = node.span;
        let count = node.children.len();
        let addr: std::net::SocketAddr = agg_addrs[idx].parse()?;
        let mut workers = Vec::with_capacity(count);
        let mut scratches = Vec::with_capacity(count);
        for i in 0..count {
            let client_id = span.0 + i as u64;
            let mut shard = vec![0.0f32; d];
            Pcg64::new(seed ^ client_id).fill_gaussian_f32(&mut shard);
            workers.push(Worker {
                client_id,
                shard: vec![shard],
                protocol: ProtocolConfig::parse(spec, d)?.build()?,
                update: mean_update(),
                seed,
            });
            scratches.push(EncodeScratch::default());
        }
        swarms.push(Swarm::spawn_mux(addr, count, n_tenants, move |i, env| match &env.msg {
            Message::RoundStart { round, shared_seed, dim, payload } => workers[i]
                .step_seeded(env.session, *round, *shared_seed, *dim, payload, &mut scratches[i])
                .ok()
                .map(|msg| Envelope { session: env.session, msg }),
            _ => None,
        })?);
    }

    // One leader per tenant over a shared mux: every session rides the
    // same 16 root connections.
    let mux = SessionMux::new(leader_binding.accept(tier.len())?);
    let mut leaders = Vec::with_capacity(n_tenants);
    for &s in &sessions {
        let proto = ProtocolConfig::parse(spec, d)?.build()?;
        leaders.push(
            Leader::new(proto, Box::new(mux.view(s)), seed)
                .with_session(s)
                .with_decode_threads(2),
        );
    }
    let connect_ms = t_start.elapsed().as_millis();
    println!("soak: tree up ({} sockets) in {connect_ms} ms", n + tier.len());

    let mut round_ms = Vec::new();
    for round in 0..rounds {
        let t0 = Instant::now();
        // Alternate drive order so each round parks some tenant's
        // envelopes in the mux at least once.
        let order: Vec<usize> = if round % 2 == 0 {
            (0..leaders.len()).collect()
        } else {
            (0..leaders.len()).rev().collect()
        };
        for i in order {
            let out = leaders[i].round(round, d as u32, &[])?;
            anyhow::ensure!(
                out.n_frames == n,
                "round {round} session {}: {} of {n} frames",
                sessions[i],
                out.n_frames
            );
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        println!("soak: round {round} closed across {n_tenants} sessions in {ms:.1} ms");
        round_ms.push(ms);
    }
    let (down, up) = mux.bytes_moved();
    let session_bytes: Vec<(u64, u64)> = sessions.iter().map(|&s| mux.session_bytes(s)).collect();
    for leader in &mut leaders {
        leader.shutdown()?;
    }
    for h in agg_threads {
        h.join().expect("aggregator thread panicked")?;
    }
    for s in swarms {
        let report = s.join()?;
        anyhow::ensure!(
            report.replies_sent == report.connected as u64 * rounds * n_tenants as u64,
            "swarm under-replied: {report:?}"
        );
    }
    let total_ms = t_start.elapsed().as_millis() as u64;
    println!("soak: total {total_ms} ms, root traffic down={down} up={up} bytes");
    for (&s, &(s_down, s_up)) in sessions.iter().zip(&session_bytes) {
        println!("soak: session {s} down={s_down} up={s_up} bytes");
    }

    let rows: Vec<String> = round_ms.iter().map(|ms| format!("{ms:.2}")).collect();
    let downs: Vec<String> = session_bytes.iter().map(|(b, _)| b.to_string()).collect();
    let ups: Vec<String> = session_bytes.iter().map(|(_, b)| b.to_string()).collect();
    let json = format!(
        "{{\"bench\": \"soak_tree\", \"transport\": \"reactor\", \"n\": {n}, \
         \"tenants\": {n_tenants}, \"aggregators\": {}, \"dim\": {d}, \"spec\": \"{spec}\", \
         \"rounds\": {rounds}, \"connect_ms\": {connect_ms}, \"round_ms\": [{}], \
         \"total_ms\": {total_ms}, \"budget_ms\": {budget_ms}, \"root_down_bytes\": {down}, \
         \"root_up_bytes\": {up}, \"session_down_bytes\": [{}], \"session_up_bytes\": [{}]}}\n",
        tier.len(),
        rows.join(", "),
        downs.join(", "),
        ups.join(", "),
    );
    if let Some(path) = json_path {
        std::fs::write(&path, &json)?;
        println!("wrote {path}");
    } else {
        print!("{json}");
    }

    // The hard budget: a hung barrier, a lost Shutdown, or a reactor
    // stall shows up here as a failed bench, not a silent slow CI run.
    anyhow::ensure!(
        total_ms <= budget_ms,
        "soak blew its wall-clock budget: {total_ms} ms > {budget_ms} ms"
    );
    Ok(())
}
