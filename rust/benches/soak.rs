//! Soak: a depth-2 TCP aggregation tree under the reactor transport,
//! full protocol traffic, hard wall-clock budget.
//!
//! Shape: one leader (reactor hub) fans in 16 aggregators; each
//! aggregator (its own reactor hub) serves its span of simulated
//! clients, driven by one [`Swarm`] thread per aggregator running real
//! `Worker::step_with` encodes (spec `binary`, d = 512). At the default
//! n = 2048 that is 2048 live sockets and ~34 threads (16 aggregators +
//! 16 swarm drivers + 17 reactors), never a thread per client.
//!
//! Knobs (env): `DME_SOAK_N` (default 2048), `DME_SOAK_ROUNDS` (5),
//! `DME_SOAK_BUDGET_MS` (60000 — the run **asserts** it finishes under
//! this). `--json out.json` writes round latencies for the CI artifact.

#[cfg(not(target_os = "linux"))]
fn main() {
    eprintln!("soak bench requires linux (epoll reactor transport)");
}

#[cfg(target_os = "linux")]
fn main() -> anyhow::Result<()> {
    use std::sync::mpsc;
    use std::time::Instant;

    use dme::coordinator::aggregator::Aggregator;
    use dme::coordinator::leader::Leader;
    use dme::coordinator::reactor::raise_nofile_limit;
    use dme::coordinator::swarm::Swarm;
    use dme::coordinator::topology::Topology;
    use dme::coordinator::transport::{
        DEFAULT_CONNECT_RETRIES, HubBinding, Message, TcpEndpoint, Transport,
    };
    use dme::coordinator::worker::{mean_update, Worker};
    use dme::protocol::config::ProtocolConfig;
    use dme::protocol::EncodeScratch;
    use dme::rng::Pcg64;

    let env_num = |key: &str, default: u64| -> u64 {
        std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let n = env_num("DME_SOAK_N", 2048) as usize;
    let rounds = env_num("DME_SOAK_ROUNDS", 5);
    let budget_ms = env_num("DME_SOAK_BUDGET_MS", 60_000);
    let d = 512usize;
    let spec = "binary";
    let seed = 41u64;
    let n_aggs = 16usize;
    let fan_in = n.div_ceil(n_aggs).max(1);

    raise_nofile_limit();
    let topo = Topology::uniform(n as u64, fan_in, 2)?;
    let tier = &topo.levels()[0];
    println!(
        "soak: n={n} clients, {} aggregators (fan-in {fan_in}), d={d} {spec}, {rounds} rounds, \
         budget {budget_ms} ms",
        tier.len()
    );

    let t_start = Instant::now();
    let leader_binding = HubBinding::bind(Transport::Reactor, "127.0.0.1:0")?;
    let leader_addr = leader_binding.local_addr()?.to_string();

    // Aggregators: bind a reactor hub for their span, report its
    // address, accept their children, connect upstream with backoff.
    let (addr_tx, addr_rx) = mpsc::channel::<(usize, String)>();
    let mut agg_threads = Vec::new();
    for (idx, node) in tier.iter().enumerate() {
        let leader_addr = leader_addr.clone();
        let addr_tx = addr_tx.clone();
        let (span, id, n_children) = (node.span, node.id, node.children.len());
        agg_threads.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let proto = ProtocolConfig::parse(spec, d)?.build()?;
            let binding = HubBinding::bind(Transport::Reactor, "127.0.0.1:0")?;
            addr_tx.send((idx, binding.local_addr()?.to_string())).ok();
            let hub = binding.accept(n_children)?;
            let mut up = TcpEndpoint::connect_with_backoff(&leader_addr, DEFAULT_CONNECT_RETRIES)?;
            Aggregator::new(proto, seed, id, span).with_level(0).run(hub, &mut up)?;
            Ok(())
        }));
    }
    drop(addr_tx);
    let mut agg_addrs = vec![String::new(); tier.len()];
    for _ in 0..tier.len() {
        let (idx, addr) = addr_rx.recv()?;
        agg_addrs[idx] = addr;
    }

    // One swarm per aggregator: its span's clients on one driver thread,
    // each replying to RoundStart with a real protocol-encoded upload.
    let mut swarms = Vec::new();
    for (idx, node) in tier.iter().enumerate() {
        let span = node.span;
        let count = node.children.len();
        let addr: std::net::SocketAddr = agg_addrs[idx].parse()?;
        let mut workers = Vec::with_capacity(count);
        let mut scratches = Vec::with_capacity(count);
        for i in 0..count {
            let client_id = span.0 + i as u64;
            let mut shard = vec![0.0f32; d];
            Pcg64::new(seed ^ client_id).fill_gaussian_f32(&mut shard);
            workers.push(Worker {
                client_id,
                shard: vec![shard],
                protocol: ProtocolConfig::parse(spec, d)?.build()?,
                update: mean_update(),
                seed,
            });
            scratches.push(EncodeScratch::default());
        }
        swarms.push(Swarm::spawn(addr, count, move |i, msg| match msg {
            Message::RoundStart { round, dim, payload } => {
                workers[i].step_with(*round, *dim, payload, &mut scratches[i]).ok()
            }
            _ => None,
        })?);
    }

    let proto = ProtocolConfig::parse(spec, d)?.build()?;
    let hub = leader_binding.accept(tier.len())?;
    let mut leader = Leader::new(proto, hub, seed).with_decode_threads(2);
    let connect_ms = t_start.elapsed().as_millis();
    println!("soak: tree up ({} sockets) in {connect_ms} ms", n + tier.len());

    let mut round_ms = Vec::new();
    for round in 0..rounds {
        let t0 = Instant::now();
        let out = leader.round(round, d as u32, &[])?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(out.n_frames == n, "round {round}: {} of {n} frames", out.n_frames);
        println!("soak: round {round} closed in {ms:.1} ms ({} frames)", out.n_frames);
        round_ms.push(ms);
    }
    let (down, up) = leader.bytes_moved();
    leader.shutdown()?;
    for h in agg_threads {
        h.join().expect("aggregator thread panicked")?;
    }
    for s in swarms {
        let report = s.join()?;
        anyhow::ensure!(
            report.replies_sent == report.connected as u64 * rounds,
            "swarm under-replied: {report:?}"
        );
    }
    let total_ms = t_start.elapsed().as_millis() as u64;
    println!("soak: total {total_ms} ms, root traffic down={down} up={up} bytes");

    let rows: Vec<String> = round_ms.iter().map(|ms| format!("{ms:.2}")).collect();
    let json = format!(
        "{{\"bench\": \"soak_tree\", \"transport\": \"reactor\", \"n\": {n}, \
         \"aggregators\": {}, \"dim\": {d}, \"spec\": \"{spec}\", \"rounds\": {rounds}, \
         \"connect_ms\": {connect_ms}, \"round_ms\": [{}], \"total_ms\": {total_ms}, \
         \"budget_ms\": {budget_ms}, \"root_down_bytes\": {down}, \"root_up_bytes\": {up}}}\n",
        tier.len(),
        rows.join(", "),
    );
    if let Some(path) = json_path {
        std::fs::write(&path, &json)?;
        println!("wrote {path}");
    } else {
        print!("{json}");
    }

    // The hard budget: a hung barrier, a lost Shutdown, or a reactor
    // stall shows up here as a failed bench, not a silent slow CI run.
    anyhow::ensure!(
        total_ms <= budget_ms,
        "soak blew its wall-clock budget: {total_ms} ms > {budget_ms} ms"
    );
    Ok(())
}
