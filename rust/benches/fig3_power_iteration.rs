//! Figure 3: distributed power iteration — ℓ₂ distance to the true top
//! eigenvector vs communication cost, MNIST-like (d=1024) and CIFAR-like
//! (d=512) datasets distributed over 100 clients, k ∈ {16, 32}.
//!
//! ```bash
//! cargo bench --offline --bench fig3_power_iteration
//! ```

use dme::apps::power_iteration::{self, PowerConfig};
use dme::bench::print_table;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::report::Report;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("DME_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
    let mut report = Report::new(
        "fig3_power_iteration",
        &["dataset", "protocol", "k", "iter", "bits_per_dim", "eig_dist"],
    );

    for (ds_name, data) in [
        ("mnist", synthetic::mnist_like(1000, 7)),
        ("cifar", synthetic::cifar_like(1000, 9)),
    ] {
        let d = data.dim;
        let mut rows = Vec::new();
        for k in [16u32, 32] {
            for (label, spec) in [
                ("uniform", format!("klevel:k={k}")),
                ("rotation", format!("rotated:k={k}")),
                ("variable", format!("varlen:k={k}")),
            ] {
                let proto = ProtocolConfig::parse(&spec, d)?.build()?;
                let cfg = PowerConfig { n_clients: 100, iters, seed: 29 };
                let result = power_iteration::run(&data.rows, proto, &cfg)?;
                for r in &result.rounds {
                    report.push(vec![
                        ds_name.into(),
                        label.into(),
                        (k as u64).into(),
                        r.iter.into(),
                        (r.cum_bits as f64 / d as f64).into(),
                        r.eig_dist.into(),
                    ]);
                }
                let last = result.rounds.last().unwrap();
                rows.push(vec![
                    label.to_string(),
                    k.to_string(),
                    format!("{:.1}", last.cum_bits as f64 / d as f64),
                    format!("{:.5}", last.eig_dist),
                ]);
            }
        }
        print_table(
            &format!("Figure 3 ({ds_name}-like, d={d}): final eigenvector distance"),
            &["protocol", "k", "cum bits/dim", "L2 distance"],
            &rows,
        );
    }
    report.write(dme::report::default_dir())?;
    println!("\nseries written to reports/fig3_power_iteration.{{csv,json}}");
    println!("expected shape (paper Fig. 3): variable-length lowest error in most");
    println!("settings; rotated competitive at low bit rates; uniform worst.");
    Ok(())
}
