//! Theorem 1 / Corollary 1: the minimax communication–MSE trade-off.
//!
//! Protocol: π_svk at k = √d + 1 wrapped with client sampling π_p. For a
//! communication budget c (set via p), Corollary 1 promises
//! MSE = O(min(1, d/c)) on the unit ball. We sweep p over two decades and
//! report the product `MSE · c / d` (× avg‖X‖²⁻¹ normalization), which
//! Theorem 1 says is Θ(1) — the paper's "product of communication cost and
//! MSE scales linearly in d".
//!
//! ```bash
//! cargo bench --offline --bench minimax_tradeoff
//! ```

use std::sync::Arc;

use dme::bench::print_table;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::sampling::SampledProtocol;
use dme::protocol::{run_round, RoundCtx};
use dme::report::Report;
use dme::stats;

fn main() -> anyhow::Result<()> {
    let d = 256;
    let n = 256;
    let trials: u64 = std::env::var("DME_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(30);
    // Unit-ball data (the minimax setting): uniform on the sphere.
    let data = synthetic::unit_sphere(n, d, 11);
    let truth = stats::true_mean(&data.rows);
    let avg = stats::avg_norm_sq(&data.rows); // = 1

    let mut report = Report::new("minimax_tradeoff", &["p", "c_bits", "mse", "mse_c_over_d"]);
    let mut rows = Vec::new();
    let mut products = Vec::new();
    for p in [1.0f64, 0.5, 0.25, 0.125, 0.0625, 0.03125] {
        let k = (d as f64).sqrt() as u32 + 1;
        // Theorem 1's construction: pi_svk with the Theorem-4 span.
        let inner = ProtocolConfig::parse(&format!("varlen:k={k},span=norm"), d)?.build()?;
        let proto: Arc<dyn dme::Protocol> = if p < 1.0 {
            Arc::new(SampledProtocol::new(inner, p))
        } else {
            inner
        };
        let mut err = stats::Running::new();
        let mut bits = stats::Running::new();
        for t in 0..trials {
            let ctx = RoundCtx::new(t, 21);
            let (est, b) = run_round(proto.as_ref(), &ctx, &data.rows)?;
            err.push(stats::sq_error(&est, &truth));
            bits.push(b as f64);
        }
        let c = bits.mean();
        let product = err.mean() * c / (d as f64 * avg);
        products.push(product);
        report.push(vec![p.into(), c.into(), err.mean().into(), product.into()]);
        rows.push(vec![
            format!("{p}"),
            format!("{:.0}", c),
            format!("{:.3e}", err.mean()),
            format!("{product:.3}"),
        ]);
    }
    print_table(
        "Theorem 1: MSE * c / d should be ~constant across budgets",
        &["p", "c (bits)", "MSE", "MSE*c/d"],
        &rows,
    );
    let max = products.iter().cloned().fold(f64::MIN, f64::max);
    let min = products.iter().cloned().fold(f64::MAX, f64::min);
    println!("\nproduct spread: max/min = {:.2} (Theta(1) up to constants)", max / min);
    assert!(max / min < 6.0, "minimax product drifts: {products:?}");
    report.write(dme::report::default_dir())?;
    println!("series in reports/minimax_tradeoff.{{csv,json}}");
    Ok(())
}
