//! Microbenchmarks of the hot paths (the §Perf numbers in EXPERIMENTS.md):
//! FWHT, quantization, entropy coders, per-spec encode/decode/fold
//! throughput, the round-session encode pipeline (one-shot vs prepared,
//! 1 vs N threads), the same-run vector-vs-forced-scalar dispatch pair
//! (rotated k=16 at d=2^18), the exact carry-save fold vs a plain f64
//! fold, the encode-scratch allocation audit, the streaming leader
//! aggregation (n worker uploads, 1 vs N decode threads), the
//! dimension-shard slice/concat rows (`shard/concat@d` up to 2^20), the
//! multi-tenant session rows (`tenant/mux@t` interleaved rounds over one
//! tree), PJRT executable dispatch, a full coordinator round, and the
//! transport rows (reactor hub scale at thousands of multiplexed
//! connections, plus the same-run threads-vs-reactor per-message
//! broadcast cost pair).
//!
//! ```bash
//! cargo bench --offline --bench micro                 # full run
//! cargo bench --offline --bench micro -- --smoke      # CI fast path
//! cargo bench --offline --bench micro -- --json out.json  # machine-readable results
//! ```

use std::sync::Arc;
use std::time::Duration;

use dme::bench::Bench;
use dme::coordinator::aggregator::aggregate_tree;
use dme::coordinator::leader::{aggregate_uploads_streaming, spawn_local_cluster, Leader};
use dme::coordinator::topology::Topology;
use dme::coordinator::transport::{LoopbackHub, Message, WeightedFrame};
use dme::coordinator::worker::mean_update;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::quantizer::Span;
use dme::protocol::{run_round_par, Encoder, Frame, Protocol, RoundCtx, SlotPartial};
use dme::rng::Pcg64;
use dme::rotation::hadamard;
use dme::runtime::{ComputeBackend, NativeBackend};

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Counting allocator wrapping the system allocator: tracks live bytes
/// and the high-water mark, so the streaming-barrier case below can
/// report *peak retained memory*, not just time. `realloc`/
/// `alloc_zeroed` use the `GlobalAlloc` defaults, which route through
/// `alloc`/`dealloc` and stay counted.
struct PeakAlloc;

static LIVE_BYTES: AtomicUsize = AtomicUsize::new(0);
static PEAK_BYTES: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE_BYTES.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK_BYTES.fetch_max(live, Ordering::Relaxed);
        }
        p
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        LIVE_BYTES.fetch_sub(layout.size(), Ordering::Relaxed);
    }
}

#[global_allocator]
static PEAK_ALLOC: PeakAlloc = PeakAlloc;

/// Start a peak-measurement window: returns the baseline to pass to
/// [`peak_since`].
fn reset_peak() -> usize {
    let live = LIVE_BYTES.load(Ordering::Relaxed);
    PEAK_BYTES.store(live, Ordering::Relaxed);
    live
}

/// Peak bytes allocated *above the baseline* since [`reset_peak`].
fn peak_since(baseline: usize) -> usize {
    PEAK_BYTES.load(Ordering::Relaxed).saturating_sub(baseline)
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let mut b = Bench::new();
    if smoke {
        // CI fast path: tiny budgets, skip the largest dims. Still
        // exercises every case family so the perf-path code keeps
        // compiling and running.
        b.budget = Duration::from_millis(20);
        b.warmup = Duration::from_millis(4);
    }

    // ---- FWHT (the L1/L3 hot kernel) ----
    let fwht_dims: &[usize] = if smoke { &[256, 1024] } else { &[256, 1024, 4096, 16384] };
    for &d in fwht_dims {
        let mut rng = Pcg64::new(d as u64);
        let mut x = vec![0.0f32; d];
        rng.fill_gaussian_f32(&mut x);
        b.run(&format!("fwht d={d}"), Some(d as f64 * 4.0), || {
            hadamard::fwht(std::hint::black_box(&mut x));
        });
    }

    // ---- quantizer ----
    let quant_dims: &[usize] = if smoke { &[1024] } else { &[1024, 16384] };
    for &d in quant_dims {
        let mut rng = Pcg64::new(1);
        let mut x = vec![0.0f32; d];
        let mut u = vec![0.0f32; d];
        rng.fill_gaussian_f32(&mut x);
        rng.fill_uniform_f32(&mut u);
        let mut bins = Vec::new();
        let (xmin, s) = dme::protocol::quantizer::grid_params(&x, Span::MinMax);
        b.run(&format!("quantize k=16 d={d}"), Some(d as f64), || {
            dme::protocol::quantizer::quantize_into(
                std::hint::black_box(&x),
                &u,
                xmin,
                s,
                16,
                &mut bins,
            );
        });
    }

    // ---- entropy coders (bytes/s over the bin payload) ----
    {
        let d = 4096;
        let k = 65u32;
        let mut rng = Pcg64::new(2);
        let bins: Vec<u32> = (0..d)
            .map(|_| {
                let x = rng.next_f32();
                ((x * x * k as f32) as u32).min(k - 1)
            })
            .collect();
        let mut hist = vec![0u64; k as usize];
        for &s in &bins {
            hist[s as usize] += 1;
        }
        let model = dme::coding::arithmetic::CumTable::from_histogram(&hist)?;
        b.run("arith encode d=4096 k=65", Some(d as f64), || {
            let mut w = dme::coding::BitWriter::new();
            dme::coding::arithmetic::encode(&mut w, &model, std::hint::black_box(&bins)).unwrap();
            std::hint::black_box(w.finish());
        });
        let mut w = dme::coding::BitWriter::new();
        dme::coding::arithmetic::encode(&mut w, &model, &bins)?;
        let (bytes, bits) = w.finish();
        let mut out = Vec::new();
        b.run("arith decode d=4096 k=65", Some(d as f64), || {
            out.clear();
            let mut r = dme::coding::BitReader::with_bit_len(&bytes, bits);
            dme::coding::arithmetic::decode(&mut r, &model, d, &mut out).unwrap();
        });
        let code = dme::coding::huffman::HuffmanCode::from_histogram(&hist)?;
        b.run("huffman encode d=4096 k=65", Some(d as f64), || {
            let mut w = dme::coding::BitWriter::new();
            code.encode(&mut w, std::hint::black_box(&bins)).unwrap();
            std::hint::black_box(w.finish());
        });
        let mut w2 = dme::coding::BitWriter::new();
        code.encode(&mut w2, &bins)?;
        let (hbytes, hbits) = w2.finish();
        b.run("huffman decode d=4096 k=65", Some(d as f64), || {
            out.clear();
            let mut r = dme::coding::BitReader::with_bit_len(&hbytes, hbits);
            code.decode(&mut r, d, &mut out).unwrap();
        });
    }

    // ---- full protocol encode+decode (client+server cost per vector) ----
    {
        let d = 1024;
        let mut rng = Pcg64::new(3);
        let mut x = vec![0.0f32; d];
        rng.fill_gaussian_f32(&mut x);
        for spec in ["binary", "klevel:k=16", "rotated:k=16", "varlen:k=33"] {
            let proto = ProtocolConfig::parse(spec, d)?.build()?;
            let ctx = RoundCtx::new(0, 1);
            b.run(&format!("{spec} encode d={d}"), Some(d as f64), || {
                std::hint::black_box(proto.encode(&ctx, 0, std::hint::black_box(&x)));
            });
            let frame = proto.encode(&ctx, 0, &x).unwrap();
            b.run(&format!("{spec} decode d={d}"), Some(d as f64), || {
                let mut acc = proto.new_accumulator();
                proto.accumulate(&ctx, std::hint::black_box(&frame), &mut acc).unwrap();
                std::hint::black_box(acc);
            });
        }
    }

    // ---- per-spec encode / decode / fold throughput (coords/s) ----
    //
    // One row triple per spec in BENCH_micro.json: session encode,
    // server-side decode (accumulate_with into a recycled accumulator),
    // and the exact fold (SlotPartial::fold_frame = decode + carry-save
    // 640-bit add). `units_per_sec` is coordinates/s — divide by 1e6 for
    // the Mcoords/s table in the README.
    {
        let d = 4096;
        let mut rng = Pcg64::new(17);
        let mut x = vec![0.0f32; d];
        rng.fill_gaussian_f32(&mut x);
        let specs = [
            "float32",
            "binary",
            "klevel:k=16",
            "klevel:k=16,p=0.5",
            "klevel:k=16,q=0.5",
            "rotated:k=16",
            "varlen:k=33",
            "qsgd:k=8",
            "drive",
            "correlated:k=16",
            "correlated:base=rotated,k=16",
        ];
        for spec in specs {
            let proto = ProtocolConfig::parse(spec, d)?.build()?;
            let ctx = RoundCtx::new(0, 19);
            let state = proto.prepare(&ctx);
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let mut frame = Frame::empty();
            // A speaking stream id (client sampling silences some ids).
            let id = (0..64u64)
                .find(|&i| enc.encode_into(i, &x, &mut frame))
                .expect("no speaking client in 64 ids");
            b.run(&format!("{spec} encode d={d}"), Some(d as f64), || {
                std::hint::black_box(enc.encode_into(id, std::hint::black_box(&x), &mut frame));
            });
            let mut acc = proto.new_accumulator();
            b.run(&format!("{spec} decode d={d}"), Some(d as f64), || {
                acc.reset();
                proto.accumulate_with(&state, std::hint::black_box(&frame), &mut acc).unwrap();
            });
            let mut part = SlotPartial::empty(acc.sum.len());
            let mut scratch = proto.new_accumulator();
            b.run(&format!("{spec} fold d={d}"), Some(d as f64), || {
                part.fold_frame(proto.as_ref(), &state, &frame, 1.0, &mut scratch).unwrap();
            });
        }
    }

    // ---- round-session encode throughput: rotated(k=16), n=64 clients ----
    //
    // The before/after pair for the session refactor: `oneshot` is the
    // pre-refactor behavior (stateless encode: the rotation is re-derived
    // and every scratch buffer reallocated per client); `session` prepares
    // the round once and reuses scratch + frame buffer. `round_par` runs
    // the full encode+decode round on 1 vs N threads.
    {
        let n = 64usize;
        let dims: &[usize] = if smoke { &[1 << 10] } else { &[1 << 10, 1 << 14, 1 << 18] };
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        for &d in dims {
            let mut rng = Pcg64::new(6 + d as u64);
            let xs: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut v = vec![0.0f32; d];
                    rng.fill_gaussian_f32(&mut v);
                    v
                })
                .collect();
            let proto = ProtocolConfig::parse("rotated:k=16", d)?.build()?;
            let ctx = RoundCtx::new(0, 1);
            let units = (n * d) as f64;
            let log2d = d.trailing_zeros();
            b.run(
                &format!("rotated k=16 encode/oneshot n={n} d=2^{log2d}"),
                Some(units),
                || {
                    for (i, x) in xs.iter().enumerate() {
                        std::hint::black_box(proto.encode(&ctx, i as u64, x));
                    }
                },
            );
            let state = proto.prepare(&ctx);
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let mut frame = Frame::empty();
            b.run(
                &format!("rotated k=16 encode/session n={n} d=2^{log2d}"),
                Some(units),
                || {
                    for (i, x) in xs.iter().enumerate() {
                        std::hint::black_box(enc.encode_into(i as u64, x, &mut frame));
                    }
                },
            );
            for t in [1usize, threads] {
                b.run(
                    &format!("rotated k=16 round_par t={t} n={n} d=2^{log2d}"),
                    Some(units),
                    || {
                        std::hint::black_box(
                            run_round_par(proto.as_ref(), &ctx, &xs, t).unwrap(),
                        );
                    },
                );
            }
        }
    }

    // ---- dispatch: vector vs forced-scalar, same run (rotated k=16, d=2^18) ----
    //
    // The acceptance pair for the SIMD hot path: identical inputs, one
    // process, toggling only the scalar-fallback override between rows.
    // Frames are asserted bit-identical before timing. On a machine
    // without AVX2 (or under `--no-default-features`) both rows measure
    // the scalar path and the ratio reads ≈ 1×.
    {
        let d = 1 << 18;
        let mut rng = Pcg64::new(23);
        let mut x = vec![0.0f32; d];
        rng.fill_gaussian_f32(&mut x);
        let proto = ProtocolConfig::parse("rotated:k=16", d)?.build()?;
        let ctx = RoundCtx::new(0, 29);
        let state = proto.prepare(&ctx);
        let mut enc = Encoder::new(proto.as_ref(), &state);
        let mut frame = Frame::empty();
        // Conformance gate before timing: both paths, same bits.
        let prev = dme::simd::set_force_scalar(true);
        enc.encode_into(0, &x, &mut frame);
        let scalar_bytes = frame.bytes.clone();
        dme::simd::set_force_scalar(false);
        enc.encode_into(0, &x, &mut frame);
        dme::simd::set_force_scalar(prev);
        assert_eq!(frame.bytes, scalar_bytes, "vector encode diverged from scalar");

        let mut thr = [[0.0f64; 2]; 2]; // [encode, decode] × [vector, scalar]
        for (pi, (label, force)) in [("vector", false), ("scalar", true)].iter().enumerate() {
            let prev = dme::simd::set_force_scalar(*force);
            let t = b.run(&format!("rotated k=16 encode d=2^18 {label}"), Some(d as f64), || {
                std::hint::black_box(enc.encode_into(0, std::hint::black_box(&x), &mut frame));
            });
            thr[0][pi] = t.throughput().unwrap_or(0.0);
            let mut acc = proto.new_accumulator();
            let t = b.run(&format!("rotated k=16 decode d=2^18 {label}"), Some(d as f64), || {
                acc.reset();
                proto.accumulate_with(&state, std::hint::black_box(&frame), &mut acc).unwrap();
            });
            thr[1][pi] = t.throughput().unwrap_or(0.0);
            dme::simd::set_force_scalar(prev);
        }
        dme::bench::print_table(
            &format!(
                "vector vs scalar dispatch, same run (rotated k=16 d=2^18, active path: {})",
                dme::simd::active_path()
            ),
            &["stage", "vector Mcoords/s", "scalar Mcoords/s", "speedup"],
            &[
                vec![
                    "encode".into(),
                    format!("{:.1}", thr[0][0] / 1e6),
                    format!("{:.1}", thr[0][1] / 1e6),
                    format!("{:.2}x", thr[0][0] / thr[0][1].max(1e-9)),
                ],
                vec![
                    "decode".into(),
                    format!("{:.1}", thr[1][0] / 1e6),
                    format!("{:.1}", thr[1][1] / 1e6),
                    format!("{:.2}x", thr[1][0] / thr[1][1].max(1e-9)),
                ],
            ],
        );
    }

    // ---- exact carry-save fold vs a plain f64 fold ----
    //
    // The cost of the determinism contract, recorded honestly: the
    // carry-save SlotPartial fold (finiteness validation + one exact
    // 640-bit windowed add per coordinate) against the naive
    // `acc[j] += v[j]` f64 fold, which has no fold-order guarantee at
    // all. State memory is part of each row name: 16 B/coord for the
    // window vector vs 8 B/coord for the f64 vector — exactly 2× while
    // nothing spills (the spill tier allocates lazily, and Gaussian
    // same-scale folds never reach it).
    {
        let d = 1 << 14;
        let n = 64usize;
        let mut rng = Pcg64::new(37);
        let values: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut v);
                v
            })
            .collect();
        let units = (n * d) as f64;
        let mut facc = vec![0.0f64; d];
        let t = b.run(&format!("fold f64 naive d=2^14 n={n} (8B/coord)"), Some(units), || {
            for v in &values {
                for (a, &x) in facc.iter_mut().zip(v) {
                    *a += x as f64;
                }
            }
            std::hint::black_box(&mut facc);
        });
        let f64_thr = t.throughput().unwrap_or(0.0);
        let base = reset_peak();
        let mut part = SlotPartial::empty(d);
        let carry_state_bytes = peak_since(base);
        let t = b.run(&format!("fold carry-save d=2^14 n={n} (16B/coord)"), Some(units), || {
            for v in &values {
                part.add_decoded(v, 1.0, 1).unwrap();
            }
        });
        let carry_thr = t.throughput().unwrap_or(0.0);
        dme::bench::print_table(
            "exact carry-save fold vs plain f64 fold (d=2^14)",
            &["fold", "Mcoords/s", "state bytes", "notes"],
            &[
                vec![
                    "f64 +=".into(),
                    format!("{:.1}", f64_thr / 1e6),
                    format!("{}", 8 * d),
                    "no fold-order guarantee".into(),
                ],
                vec![
                    "carry-save exact".into(),
                    format!("{:.1}", carry_thr / 1e6),
                    format!("{carry_state_bytes}"),
                    format!(
                        "{:.2}x slower, bit-identical under any merge tree",
                        f64_thr / carry_thr.max(1e-9)
                    ),
                ],
            ],
        );
    }

    // ---- streaming leader aggregation: decode n uploads, 1 vs N threads ----
    //
    // The server-side half of a round in isolation: n pre-encoded worker
    // uploads pushed through `aggregate_uploads_streaming` (decode into
    // per-slot partials + deterministic client-order merge). The 1-thread
    // and N-thread rows are bit-identical by construction; the delta is
    // pure decode parallelism.
    {
        let d = 1024;
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        let worker_counts: &[usize] = if smoke { &[64] } else { &[64, 512] };
        for &n in worker_counts {
            let proto = ProtocolConfig::parse("rotated:k=16", d)?.build()?;
            let ctx = RoundCtx::new(0, 21);
            let state = proto.prepare(&ctx);
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let mut rng = Pcg64::new(7 + n as u64);
            let uploads: Vec<(u64, Vec<WeightedFrame>)> = (0..n)
                .map(|i| {
                    let mut x = vec![0.0f32; d];
                    rng.fill_gaussian_f32(&mut x);
                    let frame = enc.encode(i as u64, &x).expect("encode");
                    (i as u64, vec![WeightedFrame { frame, weight: 1.0 }])
                })
                .collect();
            for t in [1usize, threads] {
                b.run(
                    &format!("leader decode rotated k=16 n={n} t={t} d={d}"),
                    Some((n * d) as f64),
                    || {
                        std::hint::black_box(
                            aggregate_uploads_streaming(proto.as_ref(), &state, &uploads, t)
                                .unwrap(),
                        );
                    },
                );
            }
        }
    }

    // ---- streaming-barrier peak memory: eager per-thread fold ----
    //
    // The PR-4 perf item, closed: the live streaming barrier folds each
    // decoded upload into a per-decode-thread SlotPartial accumulator
    // the moment it decodes (exact 640-bit merges make that
    // bit-identical by construction), so peak retention is
    // O(threads·dim) — versus the batch path, which by design holds all
    // n decoded uploads (O(n·dim)) until the merge. Measured with a
    // counting global allocator at n=4096, one-shot (peak is a property
    // of one pass, not a timing).
    {
        let d = 256;
        let n: usize = 4096;
        let threads = 4;
        let seed = 77u64;
        let proto = ProtocolConfig::parse("klevel:k=16", d)?.build()?;
        let ctx = RoundCtx::new(0, seed);
        let state = proto.prepare(&ctx);
        let mut enc = Encoder::new(proto.as_ref(), &state);
        let mut rng = Pcg64::new(13);
        let uploads: Vec<(u64, Vec<WeightedFrame>)> = (0..n)
            .map(|i| {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut x);
                let frame = enc.encode(i as u64, &x).expect("encode");
                (i as u64, vec![WeightedFrame { frame, weight: 1.0 }])
            })
            .collect();

        // Batch path: decode_all retains every DecodedUpload, then merges.
        let base = reset_peak();
        let batch_out = aggregate_uploads_streaming(proto.as_ref(), &state, &uploads, threads)?;
        let batch_peak = peak_since(base);

        // Live streaming barrier: pre-queue the same uploads on a
        // loopback hub (allocated *before* the measurement window), then
        // run the real Leader::round with its eager per-thread fold.
        let (hub, endpoints) = LoopbackHub::new(n);
        for (i, frames) in &uploads {
            endpoints[*i as usize].send(Message::Upload {
                client: *i,
                round: 0,
                frames: frames.clone(),
            })?;
        }
        let mut leader =
            Leader::new(proto.clone(), Box::new(hub), seed).with_decode_threads(threads);
        let base = reset_peak();
        let eager_out = leader.round(0, d as u32, &[])?;
        let eager_peak = peak_since(base);
        drop(endpoints); // kept alive through the round (hub broadcast targets)

        // Same bits — the eager fold is a memory optimization, not a
        // numerical change.
        assert_eq!(batch_out.n_frames, eager_out.n_frames);
        for (a, b) in batch_out.means.iter().zip(&eager_out.means) {
            assert_eq!(
                a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "eager fold changed the bits"
            );
        }
        assert!(
            eager_peak < batch_peak / 2,
            "eager barrier peak {eager_peak} B not clearly below batch {batch_peak} B"
        );
        dme::bench::print_table(
            &format!("streaming barrier peak retained memory (n={n}, d={d}, {threads} decode threads)"),
            &["path", "peak bytes", "vs batch"],
            &[
                vec![
                    "batch decode-then-merge (O(n·dim))".into(),
                    format!("{batch_peak}"),
                    "1.00x".into(),
                ],
                vec![
                    "live barrier, eager fold (O(threads·dim))".into(),
                    format!("{eager_peak}"),
                    format!("{:.3}x", eager_peak as f64 / batch_peak as f64),
                ],
            ],
        );
    }

    // ---- encode-scratch hoisting: steady-state allocation audit ----
    //
    // The scratch-reuse contract, enforced: a warm encode session
    // (persistent EncodeScratch + recycled frame — the worker loop and
    // probe driver path) must be allocation-free, and the calibration
    // fitter must reuse one probe set + one scratch across every spec it
    // fits at a dimension, so only the *first* fit at a dim pays for
    // probe generation. Measured with the counting global allocator.
    {
        let d = 4096;
        let mut rng = Pcg64::new(41);
        let mut x = vec![0.0f32; d];
        rng.fill_gaussian_f32(&mut x);
        let proto = ProtocolConfig::parse("rotated:k=16", d)?.build()?;
        let ctx = RoundCtx::new(0, 43);
        let state = proto.prepare(&ctx);
        let mut enc = Encoder::new(proto.as_ref(), &state);
        let mut frame = Frame::empty();
        for i in 0..4 {
            enc.encode_into(i, &x, &mut frame); // grow all scratch to final size
        }
        let base = reset_peak();
        for i in 0..256u64 {
            std::hint::black_box(enc.encode_into(i, &x, &mut frame));
        }
        let warm_alloc = peak_since(base);
        assert_eq!(warm_alloc, 0, "warm session encode allocated {warm_alloc} B");

        let mut cal = dme::rate::Calibration::new(47);
        let base = reset_peak();
        cal.fit(&ProtocolConfig::parse("rotated:k=16", d)?)?;
        let cold_fit = peak_since(base);
        let base = reset_peak();
        cal.fit(&ProtocolConfig::parse("klevel:k=16", d)?)?;
        cal.fit(&ProtocolConfig::parse("binary", d)?)?;
        let warm_fits = peak_since(base);
        assert!(
            warm_fits < cold_fit,
            "two warm calibration fits ({warm_fits} B) should allocate less than the one \
             cold fit that generated the d={d} probe set ({cold_fit} B)"
        );
        dme::bench::print_table(
            "encode-scratch hoisting (counting allocator, d=4096)",
            &["path", "peak bytes above baseline"],
            &[
                vec!["warm session encode ×256 (rotated k=16)".into(), format!("{warm_alloc}")],
                vec![
                    "calibration: first fit at dim (probe gen + scratch)".into(),
                    format!("{cold_fit}"),
                ],
                vec![
                    "calibration: two more specs at dim (probe + scratch reused)".into(),
                    format!("{warm_fits}"),
                ],
            ],
        );
    }

    // ---- aggregation tier: flat vs 2-level vs 3-level trees ----
    //
    // The server-side fan-in of one round at n simulated clients, routed
    // through tree topologies of partial-merging aggregators (every hop
    // crosses the real PartialUpload wire serialization). All shapes are
    // bit-identical by construction (exact folds); the delta is pure
    // topology: deeper trees bound each node's fan-in, and the printed
    // root-ingress numbers show root traffic dropping from O(n · frames)
    // to O(root-fan-in · slots).
    {
        let d = 256;
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        let worker_counts: &[usize] = if smoke { &[512] } else { &[512, 4096] };
        for &n in worker_counts {
            let proto = ProtocolConfig::parse("rotated:k=16", d)?.build()?;
            let ctx = RoundCtx::new(0, 31);
            let state = proto.prepare(&ctx);
            let mut enc = Encoder::new(proto.as_ref(), &state);
            let mut rng = Pcg64::new(11 + n as u64);
            let uploads: Vec<(u64, Vec<WeightedFrame>)> = (0..n)
                .map(|i| {
                    let mut x = vec![0.0f32; d];
                    rng.fill_gaussian_f32(&mut x);
                    let frame = enc.encode(i as u64, &x).expect("encode");
                    (i as u64, vec![WeightedFrame { frame, weight: 1.0 }])
                })
                .collect();
            let units = (n * d) as f64;
            let shapes: Vec<(String, Topology)> = vec![
                ("flat".to_string(), Topology::flat(n as u64)),
                // Depth 2: √n-ish fan-in at both tiers.
                ("depth=2".to_string(), Topology::uniform(n as u64, 64, 2)?),
                // Depth 3: small fan-in per node.
                ("depth=3".to_string(), Topology::uniform(n as u64, 16, 3)?),
            ];
            let mut ingress = Vec::new();
            for (label, topo) in &shapes {
                let out = aggregate_tree(proto.as_ref(), &state, &uploads, topo, threads)?;
                ingress.push((label.clone(), out.tier_ingress[0]));
                b.run(
                    &format!("tree agg {label} rotated k=16 n={n} d={d}"),
                    Some(units),
                    || {
                        std::hint::black_box(
                            aggregate_tree(proto.as_ref(), &state, &uploads, topo, threads)
                                .unwrap(),
                        );
                    },
                );
            }
            let flat_root = ingress[0].1;
            for (label, bytes) in &ingress {
                println!(
                    "root ingress n={n}: {label:<8} {bytes:>12} bytes ({:.1}% of flat)",
                    *bytes as f64 / flat_root as f64 * 100.0
                );
            }
        }
    }

    // ---- dimension sharding: slice + root concat at large d ----
    //
    // The root-side cost of the sharded exact fold: slicing one
    // full-dimension SlotPartial into s contiguous shard partials (what
    // each aggregator below the root does per slot) and concatenating
    // them back (what the root does per slot). Bit-identity is asserted
    // before timing; units are coordinates of the full dimension, so
    // the rows read directly as coords/s of reassembly overhead.
    {
        let shard_dims: &[usize] = if smoke { &[1 << 14] } else { &[1 << 14, 1 << 17, 1 << 20] };
        for &d in shard_dims {
            let mut rng = Pcg64::new(51 + d as u64);
            let mut v = vec![0.0f32; d];
            rng.fill_gaussian_f32(&mut v);
            let mut part = SlotPartial::from_decoded(&v, 1.0, 1)?;
            rng.fill_gaussian_f32(&mut v);
            part.add_decoded(&v, 2.0, 1)?;
            let log2d = d.trailing_zeros();
            let s = 8u32;
            let ranges = dme::coordinator::topology::split_ranges(d, s);
            let slices: Vec<SlotPartial> = ranges
                .iter()
                .map(|&(lo, hi)| part.slice(lo as usize, hi as usize))
                .collect::<anyhow::Result<_>>()?;
            let paired: Vec<((u32, u32), &SlotPartial)> =
                ranges.iter().copied().zip(slices.iter()).collect();
            let back = SlotPartial::concat_shards(&paired, d)?;
            assert!(back == part, "shard round-trip changed the partial");
            b.run(&format!("shard/slice@d=2^{log2d} s={s}"), Some(d as f64), || {
                for &(lo, hi) in &ranges {
                    std::hint::black_box(part.slice(lo as usize, hi as usize).unwrap());
                }
            });
            b.run(&format!("shard/concat@d=2^{log2d} s={s}"), Some(d as f64), || {
                std::hint::black_box(SlotPartial::concat_shards(&paired, d).unwrap());
            });
        }
    }

    // ---- backends: native vs PJRT dispatch ----
    {
        let d = 1024;
        let mut rng = Pcg64::new(4);
        let mut x = vec![0.0f32; d];
        let mut sign = vec![0.0f32; d];
        let mut u = vec![0.0f32; d];
        rng.fill_gaussian_f32(&mut x);
        rng.fill_rademacher(&mut sign);
        rng.fill_uniform_f32(&mut u);
        let native = NativeBackend;
        b.run("native encode_rotated d=1024 k=16", Some(d as f64), || {
            std::hint::black_box(native.encode_rotated(&x, &sign, &u, 16).unwrap());
        });
        let mut buf = vec![0.0f32; d];
        let mut bins = Vec::new();
        b.run("native encode_rotated_in_place d=1024 k=16", Some(d as f64), || {
            buf.copy_from_slice(&x);
            std::hint::black_box(
                native.encode_rotated_in_place(&mut buf, &sign, &u, 16, &mut bins).unwrap(),
            );
        });
        if dme::runtime::artifacts::Manifest::default_dir().join("manifest.tsv").exists() {
            if let Ok(pjrt) = dme::runtime::PjrtBackend::new() {
                // warm the executable cache first
                pjrt.encode_rotated(&x, &sign, &u, 16)?;
                b.run("pjrt encode_rotated d=1024 k=16", Some(d as f64), || {
                    std::hint::black_box(pjrt.encode_rotated(&x, &sign, &u, 16).unwrap());
                });
            }
        }
    }

    // ---- coordinator round throughput (L3 end to end) ----
    {
        let d = 1024;
        let n = 16;
        let mut rng = Pcg64::new(5);
        let shards: Vec<Vec<Vec<f32>>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut v);
                vec![v]
            })
            .collect();
        let proto: Arc<dyn Protocol> =
            ProtocolConfig::parse("rotated:k=16", d)?.build()?;
        let (mut leader, handles) = spawn_local_cluster(proto, shards, mean_update(), 9);
        let mut round = 0u64;
        b.run(
            &format!("coordinator round d={d} n={n} rotated"),
            Some((n * d) as f64),
            || {
                leader.round(round, d as u32, &[]).unwrap();
                round += 1;
            },
        );
        leader.shutdown()?;
        for h in handles {
            h.join().unwrap()?;
        }
    }

    // ---- multi-tenant mux: t interleaved sessions over one tree ----
    //
    // The session-multiplexing overhead, measured end to end: t tenants
    // (same spec, distinct session ids) drive interleaved rounds through
    // one spawn_mux_tree loopback tree. Units are total client
    // coordinates folded per iteration (t · n · d), so the rows are
    // comparable across t: flat units/s means the mux adds no
    // per-tenant cost beyond the extra tenants' own work.
    {
        use dme::coordinator::aggregator::spawn_mux_tree;

        let d = 256;
        let n = 16usize;
        let mut rng = Pcg64::new(61);
        let shards: Vec<Vec<Vec<f32>>> = (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_gaussian_f32(&mut v);
                vec![v]
            })
            .collect();
        let tenant_counts: &[usize] = if smoke { &[2] } else { &[1, 2, 4] };
        for &t in tenant_counts {
            let tenants: Vec<(u16, Arc<dyn Protocol>)> = (1..=t as u16)
                .map(|s| -> anyhow::Result<(u16, Arc<dyn Protocol>)> {
                    Ok((s, ProtocolConfig::parse("klevel:k=16", d)?.build()?))
                })
                .collect::<anyhow::Result<_>>()?;
            let topo = Topology::uniform(n as u64, 4, 2)?;
            let (_mux, mut leaders, tree) =
                spawn_mux_tree(&tenants, shards.clone(), mean_update(), 9, &topo, 2, None)?;
            let mut round = 0u64;
            b.run(
                &format!("tenant/mux@t={t} n={n} d={d}"),
                Some((t * n * d) as f64),
                || {
                    for leader in leaders.iter_mut() {
                        leader.round(round, d as u32, &[]).unwrap();
                    }
                    round += 1;
                },
            );
            for leader in &mut leaders {
                leader.shutdown()?;
            }
            tree.join()?;
        }
    }

    // ---- transport scale: one reactor hub, thousands of connections ----
    //
    // The reactor's raison d'être, measured: a swarm of simulated clients
    // (multiplexed on one epoll thread — NOT n threads) connects to one
    // reactor hub, then runs a full broadcast + n-upload round. One-shot
    // rows (`iters == 1` via `Bench::record`): a 9k-connection accept
    // storm is not a steady-state measurement. n is clamped to what the
    // fd rlimit and the ephemeral-port range allow, with a printed note,
    // so the row names stay honest about what actually ran.
    #[cfg(target_os = "linux")]
    {
        use std::time::Instant;

        use dme::coordinator::reactor::raise_nofile_limit;
        use dme::coordinator::swarm::Swarm;
        use dme::coordinator::transport::{HubBinding, Transport, TransportHub};

        let (soft, _hard) = raise_nofile_limit();
        // Two fds per connection (swarm end + hub end), headroom for the
        // process, and the loopback ephemeral-port range (~28k).
        let cap = ((soft.saturating_sub(1024)) / 2).min(24_576) as usize;
        let scale_ns: &[usize] = if smoke { &[2048] } else { &[8192, 65536] };
        for &target in scale_ns {
            let n = target.min(cap);
            if n < target {
                println!(
                    "transport/reactor: clamping n={target} to {n} (nofile soft limit {soft})"
                );
            }
            let t0 = Instant::now();
            let binding = HubBinding::bind(Transport::Reactor, "127.0.0.1:0")?;
            let addr = binding.local_addr()?;
            let swarm = Swarm::spawn(addr, n, move |i, msg| match msg {
                Message::RoundStart { round, .. } => {
                    Some(Message::Upload { client: i as u64, round: *round, frames: vec![] })
                }
                _ => None,
            })?;
            let mut hub = binding.accept(n)?;
            b.record(&format!("transport/reactor/connect@n={n}"), Some(n as f64), t0.elapsed());
            let payload: Arc<[f32]> = vec![0.0f32; 16].into();
            let t0 = Instant::now();
            hub.broadcast(&Message::RoundStart { round: 0, shared_seed: 1, dim: 16, payload })?;
            for _ in 0..n {
                hub.recv()?;
            }
            b.record(&format!("transport/reactor/round@n={n}"), Some(n as f64), t0.elapsed());
            // The scaling contract: n live connections, O(1) threads
            // (main + reactor + swarm), never a thread per connection.
            let status = std::fs::read_to_string("/proc/self/status")?;
            let threads: usize = status
                .lines()
                .find_map(|l| l.strip_prefix("Threads:"))
                .map(|v| v.trim().parse().unwrap_or(usize::MAX))
                .unwrap_or(usize::MAX);
            assert!(threads < 64, "thread count {threads} at n={n}: hub is not O(1) threads");
            println!("transport/reactor n={n}: {threads} process threads while connected");
            drop(hub); // broadcasts Shutdown; the swarm drains and exits
            swarm.join()?;
        }
    }

    // ---- transport dispatch cost: threads vs reactor, same run ----
    //
    // The acceptance pair for the reactor refactor: identical traffic —
    // BATCH small broadcasts per iteration to n live connections, with
    // the swarm replying (empty upload) only to the batch's last round
    // so each iteration ends at a real delivery barrier — through the
    // thread-per-connection hub and the epoll reactor in one process.
    // `units` is messages delivered (BATCH × n), so the JSON pair reads
    // directly as per-message send cost. The reactor wins on syscalls:
    // BATCH frames coalesce into one writev per connection instead of
    // BATCH write+flush pairs per connection per round.
    #[cfg(target_os = "linux")]
    {
        use dme::coordinator::swarm::Swarm;
        use dme::coordinator::transport::{HubBinding, Transport, TransportHub};

        let n = 512usize;
        const BATCH: u64 = 16;
        let mut per_msg_ns = Vec::new();
        for transport in [Transport::Threads, Transport::Reactor] {
            let binding = HubBinding::bind(transport, "127.0.0.1:0")?;
            let addr = binding.local_addr()?;
            let swarm = Swarm::spawn(addr, n, move |i, msg| match msg {
                Message::RoundStart { round, .. } if *round % BATCH == BATCH - 1 => {
                    Some(Message::Upload { client: i as u64, round: *round, frames: vec![] })
                }
                _ => None,
            })?;
            let mut hub = binding.accept(n)?;
            let payload: Arc<[f32]> = vec![0.0f32; 16].into();
            let mut round = 0u64;
            let t = b.run(
                &format!("transport/{transport} broadcast n={n} batch={BATCH}"),
                Some(BATCH as f64 * n as f64),
                || {
                    for _ in 0..BATCH {
                        hub.broadcast(&Message::RoundStart {
                            round,
                            shared_seed: 1,
                            dim: 16,
                            payload: payload.clone(),
                        })
                        .unwrap();
                        round += 1;
                    }
                    for _ in 0..n {
                        hub.recv().unwrap();
                    }
                },
            );
            per_msg_ns.push((
                transport.to_string(),
                t.mean.as_nanos() as f64 / (BATCH as f64 * n as f64),
            ));
            drop(hub);
            swarm.join()?;
        }
        dme::bench::print_table(
            &format!("per-message broadcast cost, same run (n={n}, batch={BATCH})"),
            &["transport", "ns/message", "speedup"],
            &per_msg_ns
                .iter()
                .map(|(name, ns)| {
                    vec![
                        name.clone(),
                        format!("{ns:.0}"),
                        format!("{:.2}x", per_msg_ns[0].1 / ns.max(1e-9)),
                    ]
                })
                .collect::<Vec<_>>(),
        );
    }

    b.report("microbenchmarks (units/s are elements/s; fwht is bytes/s)");
    if let Some(path) = json_path {
        b.write_json(&path)?;
        println!("wrote {path}");
    }
    Ok(())
}
