//! Figure 1: distributed mean estimation on unbalanced Gaussian data.
//!
//! Paper setup: 1000 datapoints, d = 256; dims 1–255 ~ N(0,1), last dim
//! ~ N(100,1). Sweep quantization levels (x-axis: bits/dimension) and plot
//! MSE (y-axis) for stochastic k-level (uniform), stochastic rotated, and
//! variable-length coding. Expected shape (paper): rotation wins across
//! the board on this *unbalanced* data, dramatically at low bit rates.
//!
//! ```bash
//! cargo bench --offline --bench fig1_unbalanced
//! ```

use dme::bench::print_table;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::{run_round, RoundCtx};
use dme::report::Report;
use dme::stats;

fn main() -> anyhow::Result<()> {
    let d = 256;
    let n = 1000;
    let trials: u64 = std::env::var("DME_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(8);
    let seed = 1;
    let data = synthetic::unbalanced(n, d, 100.0, seed);
    let truth = stats::true_mean(&data.rows);

    let mut report = Report::new(
        "fig1_unbalanced",
        &["protocol", "k", "bits_per_dim", "mse"],
    );
    let mut rows = Vec::new();
    for k in [2u32, 4, 8, 16, 32] {
        for (label, spec) in [
            ("uniform", format!("klevel:k={k}")),
            ("rotation", format!("rotated:k={k}")),
            ("variable", format!("varlen:k={k}")),
        ] {
            let proto = ProtocolConfig::parse(&spec, d)?.build()?;
            let mut err = stats::Running::new();
            let mut bits = stats::Running::new();
            for t in 0..trials {
                let ctx = RoundCtx::new(t, seed);
                let (est, b) = run_round(proto.as_ref(), &ctx, &data.rows)?;
                err.push(stats::sq_error(&est, &truth));
                bits.push(b as f64);
            }
            let bpd = bits.mean() / (n * d) as f64;
            report.push(vec![
                label.into(),
                (k as u64).into(),
                bpd.into(),
                err.mean().into(),
            ]);
            rows.push(vec![
                label.to_string(),
                k.to_string(),
                format!("{bpd:.2}"),
                format!("{:.4e}", err.mean()),
            ]);
        }
    }
    print_table(
        "Figure 1: MSE on unbalanced data (n=1000, d=256, last dim ~ N(100,1))",
        &["protocol", "k", "bits/dim", "MSE"],
        &rows,
    );
    report.write(dme::report::default_dir())?;
    println!("\nseries written to reports/fig1_unbalanced.{{csv,json}}");
    println!("expected shape (paper Fig. 1): rotation << uniform at low bits;");
    println!("variable-length best asymptotically, rotation best at 1-2 bits/dim.");
    Ok(())
}
