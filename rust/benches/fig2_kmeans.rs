//! Figure 2: distributed Lloyd's objective vs communication cost on the
//! MNIST-like (d=1024) and CIFAR-like (d=512) datasets, 10 clients,
//! 10 centers, k ∈ {16, 32} quantization levels.
//!
//! The paper's x-axis is cumulative bits per dimension (∝ iterations);
//! we emit the objective after every iteration for each protocol so the
//! plotted series matches the figure's curves.
//!
//! ```bash
//! cargo bench --offline --bench fig2_kmeans
//! ```

use dme::apps::kmeans::{self, KMeansConfig};
use dme::bench::print_table;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::report::Report;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::var("DME_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(8);
    let mut report = Report::new(
        "fig2_kmeans",
        &["dataset", "protocol", "k", "iter", "bits_per_dim", "objective"],
    );

    for (ds_name, data) in [
        ("mnist", synthetic::mnist_like(600, 7)),
        ("cifar", synthetic::cifar_like(600, 9)),
    ] {
        let d = data.dim;
        let mut rows = Vec::new();
        for k in [16u32, 32] {
            for (label, spec) in [
                ("uniform", format!("klevel:k={k}")),
                ("rotation", format!("rotated:k={k}")),
                ("variable", format!("varlen:k={k}")),
            ] {
                let proto = ProtocolConfig::parse(&spec, d)?.build()?;
                let cfg = KMeansConfig { n_centers: 10, n_clients: 10, iters, seed: 17 };
                let result = kmeans::run(&data.rows, proto, &cfg)?;
                for r in &result.rounds {
                    report.push(vec![
                        ds_name.into(),
                        label.into(),
                        (k as u64).into(),
                        r.iter.into(),
                        (r.cum_bits as f64 / d as f64).into(),
                        r.objective.into(),
                    ]);
                }
                let last = result.rounds.last().unwrap();
                rows.push(vec![
                    label.to_string(),
                    k.to_string(),
                    format!("{:.1}", last.cum_bits as f64 / d as f64),
                    format!("{:.2}", last.objective),
                ]);
            }
        }
        print_table(
            &format!("Figure 2 ({ds_name}-like, d={d}): final k-means objective"),
            &["protocol", "k", "cum bits/dim", "objective"],
            &rows,
        );
    }
    report.write(dme::report::default_dir())?;
    println!("\nseries written to reports/fig2_kmeans.{{csv,json}}");
    println!("expected shape (paper Fig. 2): all quantized protocols reach the");
    println!("float32 objective; variable-length does so with the fewest bits,");
    println!("rotation competitive at low bit rates.");
    Ok(())
}
