//! Theory check (Lemmas 1 & 5, Theorem 4): measured communication cost per
//! client versus the paper's analytic budgets.
//!
//! * π_sb: exactly d + 2·32 bits (Lemma 1 with 32-bit headers).
//! * π_sk: exactly d⌈log₂k⌉ + 2·32 bits (Lemma 5).
//! * π_svk: measured ≤ Theorem 4's bound; at k = √d + 1 the rate stays
//!   O(1) bits/dim while naive coding needs ⌈log₂k⌉ ≈ ½log₂d.
//!
//! ```bash
//! cargo bench --offline --bench theory_bits
//! ```

use dme::bench::print_table;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::varlen::VarlenProtocol;
use dme::protocol::{run_round, RoundCtx};
use dme::report::Report;
use dme::stats;

fn main() -> anyhow::Result<()> {
    let trials: u64 = std::env::var("DME_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
    let mut report = Report::new(
        "theory_bits",
        &["protocol", "d", "k", "bits_per_client", "analytic", "ratio"],
    );
    let mut rows = Vec::new();

    for d in [64usize, 256, 1024] {
        let n = 16;
        let data = synthetic::gaussian(n, d, d as u64);
        let mut run_case = |spec: String, analytic: f64| -> anyhow::Result<()> {
            let proto = ProtocolConfig::parse(&spec, d).unwrap().build().unwrap();
            let mut bits = stats::Running::new();
            for t in 0..trials {
                let ctx = RoundCtx::new(t, 5);
                let (_, b) = run_round(proto.as_ref(), &ctx, &data.rows)?;
                bits.push(b as f64 / n as f64);
            }
            let measured = bits.mean();
            let ratio = measured / analytic;
            report.push(vec![
                proto.name().into(),
                d.into(),
                0u64.into(),
                measured.into(),
                analytic.into(),
                ratio.into(),
            ]);
            rows.push(vec![
                proto.name(),
                format!("{d}"),
                format!("{measured:.1}"),
                format!("{analytic:.1}"),
                format!("{ratio:.3}"),
            ]);
            assert!(ratio <= 1.0 + 1e-9, "{spec} d={d}: bits exceed analytic bound");
            Ok(())
        };

        // Lemma 1: binary = d + 64 exactly.
        run_case("binary".into(), (d + 64) as f64)?;
        // Lemma 5: k-level = d ceil(log2 k) + 64 exactly.
        for k in [4u32, 16, 32] {
            let bpc = 32 - (k - 1).leading_zeros();
            run_case(format!("klevel:k={k}"), (d as u32 * bpc + 64) as f64)?;
        }
        // Theorem 4: varlen at k = sqrt(d)+1 stays within the bound (the
        // bound is derived for the s = sqrt(2)||x|| span, so use it here).
        let k = (d as f64).sqrt() as u32 + 1;
        let bound = VarlenProtocol::new(d, k).theorem4_bits() + 64.0;
        run_case(format!("varlen:k={k},span=norm"), bound)?;
    }

    // The headline contrast: at k=sqrt(d)+1, varlen bits/dim stays flat in
    // d while fixed-width grows like log d.
    let mut contrast = Vec::new();
    for d in [64usize, 256, 1024, 4096] {
        let n = 8;
        let k = (d as f64).sqrt() as u32 + 1;
        let data = synthetic::gaussian(n, d, 3 + d as u64);
        let varlen = ProtocolConfig::parse(&format!("varlen:k={k}"), d)?.build()?;
        let ctx = RoundCtx::new(0, 9);
        let (_, bits) = run_round(varlen.as_ref(), &ctx, &data.rows)?;
        let bpd_var = bits as f64 / (n * d) as f64;
        let bpd_fixed = (32 - (k - 1).leading_zeros()) as f64;
        contrast.push(vec![
            format!("{d}"),
            format!("{k}"),
            format!("{bpd_var:.2}"),
            format!("{bpd_fixed:.0}"),
        ]);
    }
    print_table(
        "Theory: measured bits/client vs analytic (Lemmas 1, 5; Thm 4)",
        &["protocol", "d", "measured", "analytic", "ratio"],
        &rows,
    );
    print_table(
        "Theorem 4 headline: bits/dim at k=sqrt(d)+1 (varlen flat, fixed grows)",
        &["d", "k", "varlen bits/dim", "fixed bits/dim"],
        &contrast,
    );
    report.write(dme::report::default_dir())?;
    println!("\nAll budgets hold. Series in reports/theory_bits.{{csv,json}}");
    Ok(())
}
