//! Distributed power iteration with quantized uplink — the paper's
//! Figure 3 scenario on the CIFAR-like dataset (d = 512, 100 clients),
//! comparing uniform / rotated / variable-length protocols.
//!
//! ```bash
//! cargo run --release --offline --example power_iteration
//! ```

use dme::apps::power_iteration::{self, PowerConfig};
use dme::bench::print_table;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;

fn main() -> anyhow::Result<()> {
    let data = synthetic::cifar_like(1000, 11);
    let d = data.dim;
    let cfg = PowerConfig { n_clients: 100, iters: 10, seed: 29 };
    println!(
        "distributed power iteration on {} ({} points, {} clients, {} iters)",
        data.name, data.len(), cfg.n_clients, cfg.iters
    );

    let mut rows = Vec::new();
    for spec in ["float32", "klevel:k=16", "rotated:k=16", "varlen:k=16"] {
        let proto = ProtocolConfig::parse(spec, d)?.build()?;
        let name = proto.name();
        let result = power_iteration::run(&data.rows, proto, &cfg)?;
        let last = result.rounds.last().unwrap();
        rows.push(vec![
            name,
            format!("{:.5}", last.eig_dist),
            format!("{:.2}", result.bits_per_dim_per_iter),
            format!("{:.1}", last.cum_bits as f64 / 1e3),
        ]);
    }
    print_table(
        "eigenvector distance vs communication (Figure 3 scenario)",
        &["protocol", "final L2 distance", "bits/dim/iter", "total kbits"],
        &rows,
    );
    Ok(())
}
