//! Distributed Lloyd's algorithm with quantized uplink — the paper's
//! Figure 2 scenario on the MNIST-like dataset (d = 1024, 10 clients,
//! 10 centers), comparing uniform / rotated / variable-length protocols.
//!
//! ```bash
//! cargo run --release --offline --example distributed_kmeans
//! ```

use dme::apps::kmeans::{self, KMeansConfig};
use dme::bench::print_table;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;

fn main() -> anyhow::Result<()> {
    let data = synthetic::mnist_like(600, 7);
    let d = data.dim;
    let cfg = KMeansConfig { n_centers: 10, n_clients: 10, iters: 8, seed: 17 };
    println!(
        "distributed k-means on {} ({} points, {} clients, {} centers, {} iters)",
        data.name, data.len(), cfg.n_clients, cfg.n_centers, cfg.iters
    );

    let mut rows = Vec::new();
    for spec in ["float32", "klevel:k=16", "rotated:k=16", "varlen:k=16"] {
        let proto = ProtocolConfig::parse(spec, d)?.build()?;
        let name = proto.name();
        let result = kmeans::run(&data.rows, proto, &cfg)?;
        let last = result.rounds.last().unwrap();
        rows.push(vec![
            name,
            format!("{:.2}", last.objective),
            format!("{:.2}", result.bits_per_dim_per_iter),
            format!("{:.1}", last.cum_bits as f64 / 1e3),
        ]);
    }
    print_table(
        "k-means objective vs communication (Figure 2 scenario)",
        &["protocol", "final objective", "bits/dim/iter", "total kbits"],
        &rows,
    );
    println!("\nSame objective at a fraction of float32's bits — and rotated/");
    println!("varlen beat plain k-level at equal (or lower) communication.");
    Ok(())
}
