//! Quickstart: estimate a distributed mean with every protocol and compare
//! measured MSE against the paper's analytic bounds — driven through the
//! round-session API (prepare once per round, parallel round engine).
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use dme::bench::print_table;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::{run_round_par, Decoder, Encoder, RoundCtx};
use dme::stats;

fn main() -> anyhow::Result<()> {
    let d = 256;
    let n = 100;
    let trials = 20;
    let seed = 42;
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);

    let data = synthetic::gaussian(n, d, seed);
    let truth = stats::true_mean(&data.rows);
    let avg_sq = stats::avg_norm_sq(&data.rows);
    println!(
        "distributed mean estimation: n={n} clients, d={d}, {trials} trials, {threads} threads"
    );
    println!("data: {} (avg ||x||^2 = {avg_sq:.1})", data.name);

    let specs = [
        "float32",
        "binary",
        "klevel:k=16",
        "rotated:k=16",
        "varlen:k=17",
        "varlen:k=17,coder=huffman",
        "rotated:k=16,p=0.25",
    ];

    let mut rows = Vec::new();
    for spec in specs {
        let proto = ProtocolConfig::parse(spec, d)?.build()?;
        let mut err = stats::Running::new();
        let mut bits = stats::Running::new();
        for t in 0..trials {
            let ctx = RoundCtx::new(t, seed);
            // The parallel round engine: clients sharded across threads,
            // bit-identical to the sequential driver for any thread count.
            let (est, b) = run_round_par(proto.as_ref(), &ctx, &data.rows, threads)?;
            err.push(stats::sq_error(&est, &truth));
            bits.push(b as f64);
        }
        let bound = proto
            .mse_bound(n, avg_sq)
            .map(|b| format!("{b:.3e}"))
            .unwrap_or_else(|| "--".into());
        rows.push(vec![
            proto.name(),
            format!("{:.3e}", err.mean()),
            bound,
            format!("{:.2}", bits.mean() / (n * d) as f64),
        ]);
    }
    print_table(
        "quickstart: MSE vs communication",
        &["protocol", "measured MSE", "paper bound", "bits/dim/client"],
        &rows,
    );
    println!("\nNote how rotated & varlen reach far lower MSE than binary at");
    println!("comparable bits/dim — the paper's headline result (Thms 2-4).");

    // The session API spelled out: prepare the round once (the rotation is
    // sampled exactly here), encode every client through one reusable
    // Encoder, stream the frames through one Decoder.
    let proto = ProtocolConfig::parse("rotated:k=16", d)?.build()?;
    let ctx = RoundCtx::new(0, seed);
    let state = proto.prepare(&ctx);
    let mut enc = Encoder::new(proto.as_ref(), &state);
    let mut dec = Decoder::new(proto.as_ref(), &state);
    let mut frame = dme::protocol::Frame::empty();
    let mut uplink_bits = 0u64;
    for (i, x) in data.rows.iter().enumerate() {
        if enc.encode_into(i as u64, x, &mut frame) {
            uplink_bits += frame.bit_len;
            dec.push(&frame)?;
        }
    }
    let est = dec.finish(data.rows.len());
    println!(
        "\nsession API round ({}): MSE {:.3e} at {:.2} bits/dim/client",
        proto.name(),
        stats::sq_error(&est, &truth),
        uplink_bits as f64 / (n * d) as f64
    );
    Ok(())
}
