//! Quickstart: estimate a distributed mean with every protocol and compare
//! measured MSE against the paper's analytic bounds.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use dme::bench::print_table;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::protocol::{run_round, RoundCtx};
use dme::stats;

fn main() -> anyhow::Result<()> {
    let d = 256;
    let n = 100;
    let trials = 20;
    let seed = 42;

    let data = synthetic::gaussian(n, d, seed);
    let truth = stats::true_mean(&data.rows);
    let avg_sq = stats::avg_norm_sq(&data.rows);
    println!("distributed mean estimation: n={n} clients, d={d}, {trials} trials");
    println!("data: {} (avg ||x||^2 = {avg_sq:.1})", data.name);

    let specs = [
        "float32",
        "binary",
        "klevel:k=16",
        "rotated:k=16",
        "varlen:k=17",
        "varlen:k=17,coder=huffman",
        "rotated:k=16,p=0.25",
    ];

    let mut rows = Vec::new();
    for spec in specs {
        let proto = ProtocolConfig::parse(spec, d)?.build()?;
        let mut err = stats::Running::new();
        let mut bits = stats::Running::new();
        for t in 0..trials {
            let ctx = RoundCtx::new(t, seed);
            let (est, b) = run_round(proto.as_ref(), &ctx, &data.rows)?;
            err.push(stats::sq_error(&est, &truth));
            bits.push(b as f64);
        }
        let bound = proto
            .mse_bound(n, avg_sq)
            .map(|b| format!("{b:.3e}"))
            .unwrap_or_else(|| "--".into());
        rows.push(vec![
            proto.name(),
            format!("{:.3e}", err.mean()),
            bound,
            format!("{:.2}", bits.mean() / (n * d) as f64),
        ]);
    }
    print_table(
        "quickstart: MSE vs communication",
        &["protocol", "measured MSE", "paper bound", "bits/dim/client"],
        &rows,
    );
    println!("\nNote how rotated & varlen reach far lower MSE than binary at");
    println!("comparable bits/dim — the paper's headline result (Thms 2-4).");
    Ok(())
}
