//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! This is the repository's E2E validation run (recorded in
//! EXPERIMENTS.md): a simulated federated deployment where
//!
//!  * the **leader** and 10 **workers** run on the threaded coordinator
//!    with the byte-accounted transport,
//!  * each worker's encode path executes the **AOT-compiled JAX/Pallas
//!    artifacts via PJRT** (`--backend pjrt`, the default here if
//!    artifacts exist; falls back to native with a warning),
//!  * the workload is distributed Lloyd's on the MNIST-like corpus
//!    (d = 1024), then distributed power iteration on the same data —
//!    the paper's two §7 applications, back to back,
//!  * the run reports the headline metrics: objective / eigen-distance
//!    versus uplink bits, and coordinator round throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example federated_round
//! ```

use std::sync::Arc;

use dme::apps::{kmeans, power_iteration};
use dme::bench::print_table;
use dme::data::synthetic;
use dme::protocol::config::ProtocolConfig;
use dme::runtime::{artifacts::Manifest, ComputeBackend, PjrtBackend};

fn main() -> anyhow::Result<()> {
    // ---- backend: PJRT if artifacts are built ----
    let backend: Option<Arc<dyn ComputeBackend>> =
        if Manifest::default_dir().join("manifest.tsv").exists() {
            match PjrtBackend::new() {
                Ok(b) => {
                    println!("backend: PJRT (AOT JAX/Pallas artifacts)");
                    Some(Arc::new(b))
                }
                Err(e) => {
                    eprintln!("warning: PJRT unavailable ({e:#}); using native backend");
                    None
                }
            }
        } else {
            eprintln!("warning: no artifacts (run `make artifacts`); using native backend");
            None
        };

    let mk = |spec: &str, dim: usize| -> anyhow::Result<_> {
        let mut cfg = ProtocolConfig::parse(spec, dim)?;
        if let Some(b) = &backend {
            cfg = cfg.with_backend(b.clone());
        }
        cfg.build()
    };

    // ---- phase 1: distributed Lloyd's on MNIST-like (paper Fig. 2) ----
    let data = synthetic::mnist_like(400, 7);
    let d = data.dim;
    println!("\nphase 1: distributed Lloyd's on {} (d={d}, 10 clients, 10 centers)", data.name);
    let cfg = kmeans::KMeansConfig { n_centers: 10, n_clients: 10, iters: 6, seed: 17 };
    let mut rows = Vec::new();
    let t0 = std::time::Instant::now();
    for spec in ["float32", "rotated:k=16", "varlen:k=16"] {
        let proto = mk(spec, d)?;
        let name = proto.name();
        let result = kmeans::run(&data.rows, proto, &cfg)?;
        let last = result.rounds.last().unwrap();
        rows.push(vec![
            name,
            format!("{:.2}", last.objective),
            format!("{:.2}", result.bits_per_dim_per_iter),
        ]);
    }
    print_table(
        "Lloyd's objective vs communication",
        &["protocol", "final objective", "bits/dim/iter"],
        &rows,
    );

    // ---- phase 2: distributed power iteration on CIFAR-like (Fig. 3) ----
    let data2 = synthetic::cifar_like(500, 11);
    let d2 = data2.dim;
    println!("\nphase 2: distributed power iteration on {} (d={d2}, 50 clients)", data2.name);
    let pcfg = power_iteration::PowerConfig { n_clients: 50, iters: 8, seed: 29 };
    let mut rows2 = Vec::new();
    for spec in ["float32", "rotated:k=16", "varlen:k=16"] {
        let proto = mk(spec, d2)?;
        let name = proto.name();
        let result = power_iteration::run(&data2.rows, proto, &pcfg)?;
        let last = result.rounds.last().unwrap();
        rows2.push(vec![
            name,
            format!("{:.5}", last.eig_dist),
            format!("{:.2}", result.bits_per_dim_per_iter),
        ]);
    }
    print_table(
        "eigenvector distance vs communication",
        &["protocol", "final L2 dist", "bits/dim/iter"],
        &rows2,
    );

    let wall = t0.elapsed();
    println!(
        "\ne2e wall time: {:.2}s (both phases, all protocols, full coordinator stack)",
        wall.as_secs_f64()
    );
    println!("layers exercised: L3 rust coordinator -> L2 JAX graphs -> L1 Pallas kernels (PJRT)");
    Ok(())
}
