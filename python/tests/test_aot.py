"""AOT lowering tests: every entry point lowers to parseable HLO text with
the expected interface, and the manifest describes it accurately."""

import os

import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def small_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(str(out), dims=(16,), verbose=False)
    return out, manifest


def test_all_entries_lowered(small_artifacts):
    out, manifest = small_artifacts
    names = {m["name"] for m in manifest}
    for op in (
        "rotate_fwd",
        "rotate_inv",
        "quantize_minmax",
        "quantize_norm",
        "encode_rotated",
        "decode_sum",
        "decode_rotated_mean",
    ):
        assert f"{op}_d16" in names
    for m in manifest:
        path = os.path.join(str(out), m["file"])
        assert os.path.exists(path)
        text = open(path).read()
        # HLO text, not a serialized proto: must start with a module header
        # and contain an ENTRY computation.
        assert text.startswith("HloModule"), m["name"]
        assert "ENTRY" in text, m["name"]


def test_manifest_tsv_matches_json(small_artifacts):
    out, manifest = small_artifacts
    lines = open(os.path.join(str(out), "manifest.tsv")).read().splitlines()
    assert len(lines) == len(manifest)
    for line, m in zip(lines, manifest):
        fields = line.split("\t")
        assert fields[0] == m["name"]
        assert int(fields[2]) == m["dim"]
        assert int(fields[3]) == m["num_outputs"]
        shapes = [
            [int(x) for x in s.split(",")] for s in fields[4].split(";")
        ]
        assert shapes == m["inputs"]


def test_entry_shapes_are_what_rust_expects(small_artifacts):
    _, manifest = small_artifacts
    by_name = {m["name"]: m for m in manifest}
    assert by_name["rotate_fwd_d16"]["inputs"] == [[1, 16], [16]]
    assert by_name["quantize_minmax_d16"]["inputs"] == [[1, 16], [1, 16], [1, 1]]
    assert by_name["quantize_minmax_d16"]["num_outputs"] == 3
    assert by_name["decode_sum_d16"]["inputs"] == [
        [aot.DECODE_B, 16],
        [aot.DECODE_B, 1],
        [aot.DECODE_B, 1],
        [1, 1],
    ]


def test_lowered_entry_is_pure_hlo_no_custom_calls(small_artifacts):
    """interpret=True must lower Pallas to plain HLO ops (a Mosaic
    custom-call would be unexecutable on the CPU PJRT client)."""
    out, manifest = small_artifacts
    for m in manifest:
        text = open(os.path.join(str(out), m["file"])).read()
        assert "custom-call" not in text, f"{m['name']} contains a custom call"


def test_entries_for_dim_eval_shapes():
    # eval_shape agreement: lowering cannot silently change arity.
    for name, fn, specs in aot.entries_for_dim(16):
        outs = jax.eval_shape(fn, *specs)
        assert len(outs) >= 1, name


def test_decode_rotated_mean_matches_unfused_reference():
    import jax.numpy as jnp
    import numpy as np

    rng = np.random.default_rng(5)
    b, d, k = aot.DECODE_B, 16, 8
    bins = jnp.asarray(rng.integers(0, k, size=(b, d)), dtype=jnp.float32)
    xmin = jnp.asarray(rng.normal(size=(b, 1)), dtype=jnp.float32)
    s = jnp.asarray(rng.uniform(0.5, 2.0, size=(b, 1)), dtype=jnp.float32)
    km1 = jnp.full((1, 1), float(k - 1), dtype=jnp.float32)
    sign = jnp.asarray(rng.choice([-1.0, 1.0], size=d), dtype=jnp.float32)
    inv_n = jnp.full((1, 1), 1.0 / b, dtype=jnp.float32)
    fused = model.decode_rotated_mean(bins, xmin, s, km1, sign, inv_n)
    manual = model.rotate_inv(
        (model.decode_sum(bins, xmin, s, km1) / b)[None, :], sign
    )[0]
    np.testing.assert_allclose(fused, manual, rtol=1e-5, atol=1e-6)
