"""L2 model-graph tests: rotation algebra, protocol-level invariants,
and the paper's analytic bounds checked end-to-end in JAX."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _sign(rng, d):
    return jnp.asarray(rng.choice([-1.0, 1.0], size=d), dtype=jnp.float32)


def _x(rng, b, d, scale=1.0):
    return jnp.asarray(rng.standard_normal((b, d)) * scale, dtype=jnp.float32)


@pytest.mark.parametrize("d", [4, 64, 256])
def test_rotation_roundtrip_is_identity(d):
    rng = np.random.default_rng(d)
    x = _x(rng, 3, d)
    sign = _sign(rng, d)
    back = model.rotate_inv(model.rotate_fwd(x, sign), sign)
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("d", [16, 256])
def test_rotation_preserves_norm(d):
    rng = np.random.default_rng(d + 1)
    x = _x(rng, 4, d)
    sign = _sign(rng, d)
    z = model.rotate_fwd(x, sign)
    np.testing.assert_allclose(
        jnp.linalg.norm(z, axis=1), jnp.linalg.norm(x, axis=1), rtol=1e-5
    )


def test_rotation_matches_reference():
    rng = np.random.default_rng(9)
    x = _x(rng, 2, 128)
    sign = _sign(rng, 128)
    np.testing.assert_allclose(
        model.rotate_fwd(x, sign), ref.rotate_fwd(x, sign), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        model.rotate_inv(x, sign), ref.rotate_inv(x, sign), rtol=1e-4, atol=1e-5
    )


def test_rotation_shrinks_dynamic_range_on_spiky_input():
    """Lemma 7's point: after HD, max-min ~ O(sqrt(log d / d)) * ||x||.

    A one-hot vector is the worst case for direct quantization; its
    rotation is perfectly flat (|z_j| = 1/sqrt(d) for all j)."""
    d = 1024
    x = jnp.zeros((1, d), dtype=jnp.float32).at[0, 3].set(1.0)
    rng = np.random.default_rng(2)
    sign = _sign(rng, d)
    z = np.asarray(model.rotate_fwd(x, sign))
    assert z.max() - z.min() <= 2.0 / np.sqrt(d) + 1e-6
    assert 1.0 - 1e-4 <= (z.max() - z.min()) * np.sqrt(d) / 2.0 + 1e-4


@pytest.mark.parametrize("k", [2, 16])
def test_quantize_minmax_params(k):
    rng = np.random.default_rng(k)
    x = _x(rng, 4, 64)
    u = jnp.asarray(rng.uniform(size=(4, 64)), dtype=jnp.float32)
    km1 = jnp.full((1, 1), float(k - 1), dtype=jnp.float32)
    bins, xmin, s = model.quantize_minmax(x, u, km1)
    np.testing.assert_allclose(xmin[:, 0], jnp.min(x, axis=1), rtol=1e-6)
    np.testing.assert_allclose(
        (xmin + s)[:, 0], jnp.max(x, axis=1), rtol=1e-5, atol=1e-6
    )
    assert np.asarray(bins).max() <= k - 1


def test_quantize_norm_span_satisfies_theorem2_condition():
    """xmax - xmin <= s = sqrt(2)||x|| (Eq. 4), so Theorem 2 applies."""
    rng = np.random.default_rng(21)
    x = _x(rng, 8, 128)
    u = jnp.asarray(rng.uniform(size=(8, 128)), dtype=jnp.float32)
    km1 = jnp.full((1, 1), 15.0, dtype=jnp.float32)
    _, xmin, s = model.quantize_norm(x, u, km1)
    rng_span = np.asarray(jnp.max(x, axis=1) - jnp.min(x, axis=1))
    assert np.all(np.asarray(s)[:, 0] >= rng_span - 1e-5)


def test_decode_sum_matches_manual():
    rng = np.random.default_rng(31)
    b, d, k = 8, 64, 16
    bins = jnp.asarray(rng.integers(0, k, size=(b, d)), dtype=jnp.float32)
    xmin = _x(rng, b, 1)
    s = jnp.abs(_x(rng, b, 1)) + 0.1
    km1 = jnp.full((1, 1), float(k - 1), dtype=jnp.float32)
    got = model.decode_sum(bins, xmin, s, km1)
    want = jnp.sum(xmin + bins * s / (k - 1), axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_decode_sum_zero_rows_are_neutral():
    """Zero-padded rows (bins=xmin=s=0) contribute exactly 0 to the sum --
    the Rust accumulator relies on this when n is not a multiple of B."""
    d, k = 32, 8
    bins = jnp.zeros((4, d), dtype=jnp.float32)
    xmin = jnp.zeros((4, 1), dtype=jnp.float32)
    s = jnp.zeros((4, 1), dtype=jnp.float32)
    km1 = jnp.full((1, 1), float(k - 1), dtype=jnp.float32)
    out = np.asarray(model.decode_sum(bins, xmin, s, km1))
    assert np.all(out == 0.0)


def test_encode_decode_roundtrip_mse_within_theorem3_bound():
    """Full pi_srk round trip at d=256, n=8: measured MSE of the mean must
    satisfy Theorem 3: E <= (2 ln d + 2) / (n (k-1)^2) * avg ||x||^2."""
    rng = np.random.default_rng(77)
    n, d, k, trials = 8, 256, 16, 20
    xs = _x(rng, n, d)
    avg_sq = float(jnp.mean(jnp.sum(xs * xs, axis=1)))
    bound = (2 * np.log(d) + 2) / (n * (k - 1) ** 2) * avg_sq
    km1 = jnp.full((1, 1), float(k - 1), dtype=jnp.float32)
    errs = []
    for t in range(trials):
        sign = _sign(rng, d)
        ys = []
        for i in range(n):
            u = jnp.asarray(rng.uniform(size=(1, d)), dtype=jnp.float32)
            bins, xmin, s = model.encode_rotated(xs[i : i + 1], sign, u, km1)
            ys.append(model.decode_sum(bins, xmin, s, km1))
        zbar = jnp.stack(ys).mean(axis=0)[None, :]
        est = model.rotate_inv(zbar, sign)[0]
        err = jnp.sum((est - jnp.mean(xs, axis=0)) ** 2)
        errs.append(float(err))
    assert np.mean(errs) <= bound * 1.5  # bound + MC slack


def test_decode_rotated_mean_matches_composition():
    rng = np.random.default_rng(55)
    b, d, k = 8, 64, 16
    sign = _sign(rng, d)
    xs = _x(rng, b, d)
    u = jnp.asarray(rng.uniform(size=(b, d)), dtype=jnp.float32)
    km1 = jnp.full((1, 1), float(k - 1), dtype=jnp.float32)
    z = model.rotate_fwd(xs, sign)
    xmin = jnp.min(z, axis=1, keepdims=True)
    s = jnp.max(z, axis=1, keepdims=True) - xmin
    from compile.kernels import quantize as q

    bins = q.quantize_bins(z, u, xmin, s, km1)
    inv_n = jnp.full((1, 1), 1.0 / b, dtype=jnp.float32)
    fused = model.decode_rotated_mean(bins, xmin, s, km1, sign, inv_n)
    manual = model.rotate_inv(
        (model.decode_sum(bins, xmin, s, km1) / b)[None, :], sign
    )[0]
    np.testing.assert_allclose(fused, manual, rtol=1e-5, atol=1e-6)
