"""Kernel-vs-oracle tests: the CORE correctness signal for L1.

Every Pallas kernel is checked against the literal pure-jnp oracle in
kernels/ref.py, both on fixed cases and under hypothesis sweeps over
shapes, dtypes-compatible value ranges, and RNG seeds.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import hadamard, quantize, ref

jax.config.update("jax_platform_name", "cpu")

DIMS = [2, 4, 16, 64, 256]


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# ---------------------------------------------------------------------------
# FWHT kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", DIMS)
@pytest.mark.parametrize("batch", [1, 3, 8])
def test_fwht_matches_dense_reference(d, batch):
    rng = np.random.default_rng(42 + d + batch)
    x = _rand(rng, batch, d)
    got = hadamard.fwht(x)
    want = ref.fwht(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("block_b", [1, 2, 4])
def test_fwht_blocked_grid_matches_unblocked(block_b):
    rng = np.random.default_rng(7)
    x = _rand(rng, 8, 64)
    got = hadamard.fwht(x, block_b=block_b)
    want = hadamard.fwht(x)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_fwht_is_self_inverse_up_to_d():
    """H H = d I for the unnormalized transform."""
    rng = np.random.default_rng(0)
    x = _rand(rng, 4, 128)
    twice = hadamard.fwht(hadamard.fwht(x))
    np.testing.assert_allclose(twice, 128.0 * x, rtol=1e-4, atol=1e-3)


def test_fwht_preserves_norm_when_normalized():
    rng = np.random.default_rng(1)
    x = _rand(rng, 4, 256)
    z = hadamard.fwht(x) / jnp.sqrt(256.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(z, axis=1), jnp.linalg.norm(x, axis=1), rtol=1e-5
    )


def test_fwht_rejects_non_power_of_two():
    with pytest.raises(ValueError, match="power-of-two"):
        hadamard.fwht(jnp.zeros((1, 24)))


def test_fwht_rejects_bad_block():
    with pytest.raises(ValueError, match="divisible"):
        hadamard.fwht(jnp.zeros((3, 16)), block_b=2)


@hypothesis.given(
    log_d=st.integers(min_value=1, max_value=8),
    batch=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=1e-3, max_value=1e3),
)
@hypothesis.settings(max_examples=30, deadline=None)
def test_fwht_hypothesis_sweep(log_d, batch, seed, scale):
    d = 2**log_d
    rng = np.random.default_rng(seed)
    x = _rand(rng, batch, d) * scale
    got = hadamard.fwht(x)
    want = ref.fwht(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3 * scale)


# ---------------------------------------------------------------------------
# Quantization kernels
# ---------------------------------------------------------------------------


def _quant_args(rng, batch, d, k, span="minmax"):
    x = _rand(rng, batch, d)
    u = jnp.asarray(rng.uniform(size=(batch, d)), dtype=jnp.float32)
    xmin = jnp.min(x, axis=1, keepdims=True)
    if span == "minmax":
        s = jnp.max(x, axis=1, keepdims=True) - xmin
    else:
        s = jnp.sqrt(2.0) * jnp.linalg.norm(x, axis=1, keepdims=True)
    km1 = jnp.full((1, 1), float(k - 1), dtype=jnp.float32)
    return x, u, xmin, s, km1


@pytest.mark.parametrize("k", [2, 3, 16, 33])
@pytest.mark.parametrize("span", ["minmax", "norm"])
def test_quantize_matches_reference(k, span):
    rng = np.random.default_rng(5 + k)
    x, u, xmin, s, km1 = _quant_args(rng, 4, 64, k, span)
    got = quantize.quantize_bins(x, u, xmin, s, km1)
    want = ref.quantize_bins(x, u, xmin, s, km1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("k", [2, 16])
def test_quantize_bins_are_integral_in_range(k):
    rng = np.random.default_rng(11)
    x, u, xmin, s, km1 = _quant_args(rng, 8, 128, k)
    bins = np.asarray(quantize.quantize_bins(x, u, xmin, s, km1))
    assert np.all(bins == np.round(bins))
    assert bins.min() >= 0.0
    assert bins.max() <= k - 1


def test_quantize_constant_vector_is_safe():
    """s == 0 (constant row) must not divide by zero; bins are all 0."""
    x = jnp.full((2, 16), 3.25, dtype=jnp.float32)
    u = jnp.full((2, 16), 0.5, dtype=jnp.float32)
    xmin = jnp.full((2, 1), 3.25, dtype=jnp.float32)
    s = jnp.zeros((2, 1), dtype=jnp.float32)
    km1 = jnp.full((1, 1), 15.0, dtype=jnp.float32)
    bins = np.asarray(quantize.quantize_bins(x, u, xmin, s, km1))
    assert np.all(np.isfinite(bins))
    assert np.all(bins == 0.0)


def test_dequantize_matches_reference():
    rng = np.random.default_rng(3)
    x, u, xmin, s, km1 = _quant_args(rng, 4, 64, 16)
    bins = quantize.quantize_bins(x, u, xmin, s, km1)
    got = quantize.dequantize(bins, xmin, s, km1)
    want = ref.dequantize(bins, xmin, s, km1)
    # rtol loose enough for f32 multiply-order differences between the
    # pallas interpreter and plain jnp (observed ~4e-6 relative).
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_dequantize_error_bounded_by_bin_width():
    """|Y - X| <= s/(k-1) per coordinate (the rounding never leaves its bin)."""
    rng = np.random.default_rng(13)
    k = 8
    x, u, xmin, s, km1 = _quant_args(rng, 8, 256, k)
    bins = quantize.quantize_bins(x, u, xmin, s, km1)
    y = quantize.dequantize(bins, xmin, s, km1)
    width = np.asarray(s) / (k - 1)
    assert np.all(np.abs(np.asarray(y - x)) <= width + 1e-5)


def test_quantize_is_unbiased_monte_carlo():
    """E[Y] = X (Section 2.2): Monte-Carlo over the private uniforms."""
    rng = np.random.default_rng(17)
    d, k, trials = 32, 4, 4000
    x = _rand(rng, 1, d)
    xmin = jnp.min(x, axis=1, keepdims=True)
    s = jnp.max(x, axis=1, keepdims=True) - xmin
    km1 = jnp.full((1, 1), float(k - 1), dtype=jnp.float32)
    xt = jnp.tile(x, (trials, 1))
    u = jnp.asarray(rng.uniform(size=(trials, d)), dtype=jnp.float32)
    bins = quantize.quantize_bins(xt, u, jnp.tile(xmin, (trials, 1)), jnp.tile(s, (trials, 1)), km1)
    y = quantize.dequantize(bins, jnp.tile(xmin, (trials, 1)), jnp.tile(s, (trials, 1)), km1)
    mean = np.asarray(jnp.mean(y, axis=0))
    width = float(s[0, 0]) / (k - 1)
    # std of mean <= width/2/sqrt(trials); 5 sigma margin
    tol = 5 * width / 2 / np.sqrt(trials)
    np.testing.assert_allclose(mean, np.asarray(x)[0], atol=tol)


@hypothesis.given(
    log_d=st.integers(min_value=1, max_value=7),
    batch=st.integers(min_value=1, max_value=4),
    k=st.integers(min_value=2, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    span=st.sampled_from(["minmax", "norm"]),
)
@hypothesis.settings(max_examples=40, deadline=None)
def test_quantize_hypothesis_sweep(log_d, batch, k, seed, span):
    d = 2**log_d
    rng = np.random.default_rng(seed)
    x, u, xmin, s, km1 = _quant_args(rng, batch, d, k, span)
    got = quantize.quantize_bins(x, u, xmin, s, km1)
    want = ref.quantize_bins(x, u, xmin, s, km1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    y = np.asarray(quantize.dequantize(got, xmin, s, km1))
    assert np.all(np.isfinite(y))
