"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for Rust.

Emits HLO *text*, NOT a serialized HloModuleProto: jax >= 0.5 writes protos
with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/load_hlo/).

Run as `python -m compile.aot --out-dir ../artifacts` (the Makefile's
`artifacts` target). Python runs ONCE here, at build time; the Rust binary
is self-contained afterwards.

Artifact naming: <entry>_d<d>.hlo.txt, plus manifest.tsv (machine-read by
rust/src/runtime/artifacts.rs) and manifest.json (for humans).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Dimension variants compiled into artifacts. 16/64 are for fast unit /
# integration tests; 256 = Figure 1, 512 = CIFAR-like, 1024 = MNIST-like.
DIMS = (16, 64, 256, 512, 1024)
# Server-side decode batch: rows per decode_sum execution; the Rust side
# zero-pads the final partial batch (zero rows dequantize to xmin=s=0 -> 0).
DECODE_B = 8
F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def entries_for_dim(d):
    """(name, fn, arg_specs) for every entry point at dimension d."""
    scal = _spec(1, 1)
    return [
        (
            f"rotate_fwd_d{d}",
            lambda x, sign: (model.rotate_fwd(x, sign),),
            (_spec(1, d), _spec(d)),
        ),
        (
            f"rotate_inv_d{d}",
            lambda z, sign: (model.rotate_inv(z, sign),),
            (_spec(1, d), _spec(d)),
        ),
        (
            f"quantize_minmax_d{d}",
            lambda x, u, km1: model.quantize_minmax(x, u, km1),
            (_spec(1, d), _spec(1, d), scal),
        ),
        (
            f"quantize_norm_d{d}",
            lambda x, u, km1: model.quantize_norm(x, u, km1),
            (_spec(1, d), _spec(1, d), scal),
        ),
        (
            f"encode_rotated_d{d}",
            lambda x, sign, u, km1: model.encode_rotated(x, sign, u, km1),
            (_spec(1, d), _spec(d), _spec(1, d), scal),
        ),
        (
            f"decode_sum_d{d}",
            lambda bins, xmin, s, km1: (model.decode_sum(bins, xmin, s, km1),),
            (_spec(DECODE_B, d), _spec(DECODE_B, 1), _spec(DECODE_B, 1), scal),
        ),
        (
            f"decode_rotated_mean_d{d}",
            lambda bins, xmin, s, km1, sign, inv_n: (
                model.decode_rotated_mean(bins, xmin, s, km1, sign, inv_n),
            ),
            (
                _spec(DECODE_B, d),
                _spec(DECODE_B, 1),
                _spec(DECODE_B, 1),
                scal,
                _spec(d),
                scal,
            ),
        ),
    ]


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir, dims=DIMS, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for d in dims:
        for name, fn, specs in entries_for_dim(d):
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            fname = f"{name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            n_out = len(jax.eval_shape(fn, *specs))
            manifest.append(
                {
                    "name": name,
                    "file": fname,
                    "dim": d,
                    "inputs": [list(s.shape) for s in specs],
                    "num_outputs": n_out,
                }
            )
            if verbose:
                print(f"lowered {name}: {len(text)} chars, {n_out} outputs")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # TSV twin for the Rust loader (no JSON parser dependency):
    # name \t file \t dim \t num_outputs \t shape;shape;...
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        for m in manifest:
            shapes = ";".join(",".join(str(x) for x in s) for s in m["inputs"])
            f.write(f"{m['name']}\t{m['file']}\t{m['dim']}\t{m['num_outputs']}\t{shapes}\n")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--dims", default=",".join(str(d) for d in DIMS),
        help="comma-separated power-of-two dims to compile",
    )
    args = ap.parse_args()
    dims = tuple(int(x) for x in args.dims.split(","))
    manifest = lower_all(args.out_dir, dims)
    # Stamp file is the Makefile's freshness witness.
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write(f"{len(manifest)} artifacts\n")
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
