"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every Pallas kernel is checked
against the function of the same name here (pytest + hypothesis sweeps in
python/tests/). They are written in the most literal style possible --
no fusion tricks, no reshape butterflies -- so bugs do not co-vary.
"""

import jax.numpy as jnp
import numpy as np


def hadamard_matrix(d):
    """Dense Walsh-Hadamard matrix (Sylvester construction), entries +-1."""
    if d & (d - 1) != 0:
        raise ValueError(f"d must be a power of two, got {d}")
    h = np.array([[1.0]])
    while h.shape[0] < d:
        h = np.block([[h, h], [h, -h]])
    return jnp.asarray(h, dtype=jnp.float32)


def fwht(x):
    """Unnormalized Walsh-Hadamard transform via the dense matrix."""
    d = x.shape[-1]
    return x @ hadamard_matrix(d).T


def rotate_fwd(x, sign):
    """z = (1/sqrt(d)) H (D x) -- the paper's R = HD, orthonormal."""
    d = x.shape[-1]
    return fwht(x * sign) / jnp.sqrt(float(d))


def rotate_inv(z, sign):
    """x = D^-1 H^-1 z = D (1/sqrt(d)) H z (H symmetric, D = D^-1)."""
    d = z.shape[-1]
    return sign * (fwht(z) / jnp.sqrt(float(d)))


def quantize_bins(x, u, xmin, s, km1):
    """Literal transcription of Section 2.2's stochastic rounding."""
    km1 = jnp.asarray(km1).reshape(())
    safe_s = jnp.where(s > 0, s, 1.0)
    t = jnp.where(s > 0, (x - xmin) / safe_s * km1, 0.0)
    lo = jnp.clip(jnp.floor(t), 0.0, km1 - 1.0)
    frac = t - lo
    b = lo + (u < frac).astype(x.dtype)
    return jnp.clip(b, 0.0, km1)


def dequantize(bins, xmin, s, km1):
    km1 = jnp.asarray(km1).reshape(())
    return xmin + bins * (s / km1)


def decode_sum(bins, xmin, s, km1):
    """Sum of dequantized rows: the server-side accumulation primitive."""
    return jnp.sum(dequantize(bins, xmin, s, km1), axis=0)
