"""L1 Pallas kernel: blocked fast Walsh-Hadamard transform (FWHT).

The paper's structured rotation is R = HD (Section 3): a Rademacher
diagonal followed by a Walsh-Hadamard transform, applied in O(d log d).
This kernel performs the *unnormalized* FWHT over the last axis of a
(batch, d) block; the caller multiplies by 1/sqrt(d) to make it
orthonormal (see model.rotate_fwd / rotate_inv).

TPU mapping (DESIGN.md "Hardware adaptation"): each (block_b, d) tile is
loaded into VMEM once, all log2(d) butterfly stages run on the tile while
resident, and the tile is written back once -- a single HBM round trip per
vector instead of one per stage. There is no matmul in this op, so the MXU
is idle by design; the kernel is memory-bandwidth bound and its roofline is
estimated from the VMEM footprint in DESIGN.md.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and all artifacts in this repo target the CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_tile(x):
    """Unnormalized FWHT of a (b, d) tile, d a power of two.

    The python loop unrolls the log2(d) butterfly stages at trace time;
    each stage pairs lanes h apart: (a, b) -> (a + b, a - b).
    """
    b, d = x.shape
    h = 1
    while h < d:
        x = x.reshape(b, d // (2 * h), 2, h)
        lo = x[:, :, 0, :]
        hi = x[:, :, 1, :]
        x = jnp.stack([lo + hi, lo - hi], axis=2)
        h *= 2
    return x.reshape(b, d)


def _fwht_kernel(x_ref, o_ref):
    o_ref[...] = _fwht_tile(x_ref[...])


@functools.partial(jax.jit, static_argnames=("block_b",))
def fwht(x, block_b=None):
    """Unnormalized fast Walsh-Hadamard transform over the last axis.

    Args:
      x: (batch, d) float array; d must be a power of two.
      block_b: rows per VMEM tile (defaults to the whole batch; the
        batch sizes used by the AOT entry points are small).

    Returns:
      (batch, d) array, H @ x[i] for each row i (H entries are +-1).
    """
    batch, d = x.shape
    if d & (d - 1) != 0:
        raise ValueError(f"FWHT needs power-of-two d, got {d}")
    if block_b is None:
        block_b = batch
    if batch % block_b != 0:
        raise ValueError(f"batch {batch} not divisible by block_b {block_b}")
    return pl.pallas_call(
        _fwht_kernel,
        grid=(batch // block_b,),
        in_specs=[pl.BlockSpec((block_b, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d), x.dtype),
        interpret=True,
    )(x)
