"""L1 Pallas kernels: stochastic k-level quantization and dequantization.

Section 2.2 of the paper: coordinate j of client i is rounded onto the
uniform grid B_i(r) = X_i^min + r * s_i / (k - 1), r in [0, k), landing on
the upper neighbour with probability proportional to the within-bin offset,
so that E[Y_i(j)] = X_i(j) (unbiased).

Randomness is an *input* (a (batch, d) tensor of uniforms in [0, 1)):
the Rust coordinator generates it from its private per-client streams, so
runs are reproducible end-to-end and Python never owns RNG state on the
request path.

k arrives as a runtime scalar (shape (1, 1)) so one artifact serves every
quantization level; only the dimension d is baked into the HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(x_ref, u_ref, xmin_ref, s_ref, km1_ref, bins_ref):
    x = x_ref[...]
    u = u_ref[...]
    xmin = xmin_ref[...]  # (b, 1), broadcasts over d
    s = s_ref[...]  # (b, 1)
    km1 = km1_ref[0, 0]  # scalar: k - 1 as float
    # Guard s == 0 (constant vector): every coordinate sits on bin 0.
    inv = jnp.where(s > 0, km1 / jnp.where(s > 0, s, 1.0), 0.0)
    t = (x - xmin) * inv
    lo = jnp.clip(jnp.floor(t), 0.0, km1 - 1.0)
    frac = t - lo
    b = lo + (u < frac).astype(x.dtype)
    bins_ref[...] = jnp.clip(b, 0.0, km1)


def _dequantize_kernel(bins_ref, xmin_ref, s_ref, km1_ref, y_ref):
    bins = bins_ref[...]
    xmin = xmin_ref[...]
    s = s_ref[...]
    km1 = km1_ref[0, 0]
    y_ref[...] = xmin + bins * (s / km1)


def _call_rowwise(kernel, outs_dtype, x_like, args, block_b=None):
    batch, d = x_like.shape
    if block_b is None:
        block_b = batch
    row_spec = pl.BlockSpec((block_b, d), lambda i: (i, 0))
    par_spec = pl.BlockSpec((block_b, 1), lambda i: (i, 0))
    scal_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    specs = []
    for a in args:
        if a.shape == x_like.shape:
            specs.append(row_spec)
        elif a.shape == (batch, 1):
            specs.append(par_spec)
        elif a.shape == (1, 1):
            specs.append(scal_spec)
        else:
            raise ValueError(f"unexpected operand shape {a.shape}")
    return pl.pallas_call(
        kernel,
        grid=(batch // block_b,),
        in_specs=specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((batch, d), outs_dtype),
        interpret=True,
    )(*args)


@jax.jit
def quantize_bins(x, u, xmin, s, km1):
    """Stochastic k-level bin assignment.

    Args:
      x: (batch, d) values to quantize.
      u: (batch, d) iid uniforms in [0, 1) (private randomness).
      xmin: (batch, 1) grid origin per row (usually row min).
      s: (batch, 1) grid span per row; the grid covers [xmin, xmin + s].
        Must satisfy xmin + s >= row max (Theorem 2's condition).
      km1: (1, 1) float, k - 1 (number of grid cells).

    Returns:
      (batch, d) float array of integral bin indices in [0, k-1].
      (float-typed: d <= 2^23 and k <= 2^23 keep them exact; the Rust
      side casts to integers for entropy coding.)
    """
    return _call_rowwise(_quantize_kernel, x.dtype, x, (x, u, xmin, s, km1))


@jax.jit
def dequantize(bins, xmin, s, km1):
    """Inverse of quantize_bins: Y(j) = xmin + bins(j) * s / (k - 1)."""
    return _call_rowwise(_dequantize_kernel, bins.dtype, bins, (bins, xmin, s, km1))
