"""L1: Pallas kernels for the paper's compute hot-spots.

- hadamard: blocked fast Walsh-Hadamard transform (the R = HD rotation).
- quantize: stochastic k-level quantization / dequantization.
- ref: pure-jnp oracles the kernels are tested against.
"""

from . import hadamard, quantize, ref  # noqa: F401
